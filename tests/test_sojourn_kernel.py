"""Parity pins for the accelerator-resident sojourn sweep.

Layered contract (see docs/architecture.md, "Sweep backends"):

* **f64 / reference layer** — the scan-formulated
  :func:`repro.kernels.sojourn_sweep.ref.sojourn_cell_reference` must be
  BIT-IDENTICAL to every legacy heap-event recursion in
  ``repro.core.simulator`` (plain/clone/relaunch/hedged) at float64.
* **f32 / device layer** — the numpy reference, the jit+vmap backend and
  the Pallas kernel (interpret mode on CPU) must be bit-identical to each
  other at the SAME dtype; ``shard_map`` over a degenerate one-device
  mesh must not change a single bit.
* **end-to-end layer** — ``sweep_sojourn_policies(backend='jax')`` runs
  the device path at float32, so it is compared to the numpy path at
  distribution level (means/quantiles), not per-sample: rare borderline
  trigger events legitimately land on the other side at f32.

Plus the satellite pins: backend provenance on results/Plan, custom
``worker_batches`` parity, and the tuner's measured-replan-time cooldown
waiver.
"""

import numpy as np
import pytest

from repro.core import simulator as S
from repro.core.order_stats import Empirical, Exponential, ShiftedExponential
from repro.core.planner import PolicyCandidate, make_planner, ClusterSpec, Objective
from repro.core.replication import ReplicationPlan
from repro.core.tuner import StragglerTuner, TunerConfig
from repro.kernels.sojourn_sweep import ref as R
from repro.kernels.sojourn_sweep import ops as O


def _random_cell(rng, j_hi=60, g_hi=6):
    n_jobs = int(rng.integers(5, j_hi))
    n_groups = int(rng.integers(1, g_hi))
    arr = np.cumsum(rng.exponential(1.0 / rng.uniform(0.2, 3.0), n_jobs))
    svc = rng.exponential(1.0, (n_jobs, n_groups)) + rng.uniform(0, 0.5)
    alt = rng.exponential(1.0, (n_jobs, n_groups)) + rng.uniform(0, 0.5)
    thr = float(np.quantile(svc, rng.uniform(0.3, 0.95)))
    return arr, svc, alt, thr, n_groups


def test_reference_bit_matches_legacy_recursions():
    """f64 layer: the scan reference IS the heap simulation, bit for bit,
    for all four policy kinds across randomized cells."""
    rng = np.random.default_rng(0)
    for _ in range(40):
        arr, svc, alt, thr, g = _random_cell(rng)
        n_jobs = arr.size

        out, _ = R.sojourn_cell_reference(arr, svc, alt, R.KIND_NONE,
                                          np.inf, None, g)
        np.testing.assert_array_equal(out, S._sojourn_recursion(arr, svc, g))

        out, x = R.sojourn_cell_reference(arr, svc, alt, R.KIND_CLONE,
                                          thr, None, g)
        leg, leg_x = S._sojourn_recursion_speculative(arr, svc, alt, g, thr)
        np.testing.assert_array_equal(out, leg)
        assert x == leg_x

        out, x = R.sojourn_cell_reference(arr, svc, alt, R.KIND_RELAUNCH,
                                          thr, None, g)
        leg, leg_x = S._sojourn_recursion_relaunch(arr, svc, alt, g, thr)
        np.testing.assert_array_equal(out, leg)
        assert x == leg_x

        frac = float(rng.uniform(0.0, 1.0))
        hm = O.hedge_mask(n_jobs, frac)
        out, x = R.sojourn_cell_reference(arr, svc, alt, R.KIND_HEDGED,
                                          np.inf, hm, g)
        leg, leg_x = S._sojourn_recursion_hedged(arr, svc, alt, g, frac)
        np.testing.assert_array_equal(out, leg)
        assert x == leg_x


@pytest.fixture(scope="module")
def cell_batch():
    """One (cells, policies) batch shared by the device-layer tests."""
    rng = np.random.default_rng(7)
    n_jobs, n_g, n_cells = 40, 4, 3
    arr = np.cumsum(rng.exponential(0.5, n_jobs)).astype(np.float32)
    svc = (rng.exponential(1.0, (n_cells, n_jobs, n_g)) + 0.1).astype(np.float32)
    alt = (rng.exponential(1.0, (n_cells, n_jobs, n_g)) + 0.1).astype(np.float32)
    kinds = np.array([R.KIND_NONE, R.KIND_CLONE, R.KIND_RELAUNCH,
                      R.KIND_HEDGED], np.int32)
    thr = np.full((n_cells, 4), np.inf, np.float32)
    thr[:, 1] = np.quantile(svc.astype(np.float64), 0.8, axis=(1, 2))
    thr[:, 2] = np.quantile(svc.astype(np.float64), 0.9, axis=(1, 2))
    hm = np.stack([O.hedge_mask(n_jobs, f) for f in (0.0, 0.0, 0.0, 0.4)])
    ng = np.array([1, 2, 4], np.int32)
    return arr, svc, alt, kinds, thr, hm, ng


def test_jax_and_pallas_bit_match_reference(cell_batch):
    """f32 layer: same dtype in, identical bits out of all three backends
    (the Pallas kernel runs the SAME jnp body as the vmap path, and both
    must reproduce the numpy reference exactly)."""
    out_np, x_np = O.sojourn_policy_cells(*cell_batch, backend="numpy")
    out_jx, x_jx = O.sojourn_policy_cells(*cell_batch, backend="jax")
    out_pl, x_pl = O.sojourn_policy_cells(*cell_batch, backend="pallas")
    np.testing.assert_array_equal(out_np, np.asarray(out_jx))
    np.testing.assert_array_equal(x_np, np.asarray(x_jx))
    np.testing.assert_array_equal(np.asarray(out_jx), np.asarray(out_pl))
    np.testing.assert_array_equal(np.asarray(x_jx), np.asarray(x_pl))


def test_shard_map_degenerate_mesh_is_bit_identical(cell_batch):
    """shard_map over the trivial one-device CPU mesh (the tier-1 stand-in
    for a real fleet mesh) must not change a single bit vs plain jit."""
    out_jx, x_jx = O.sojourn_policy_cells(*cell_batch, backend="jax")
    mesh = O.cells_mesh()
    out_sm, x_sm = O.sojourn_policy_cells(*cell_batch, backend="jax",
                                          mesh=mesh)
    np.testing.assert_array_equal(np.asarray(out_jx), np.asarray(out_sm))
    np.testing.assert_array_equal(np.asarray(x_jx), np.asarray(x_sm))


def test_resolve_backend_knobs():
    assert S.resolve_sweep_backend("numpy") == "numpy"
    assert S.resolve_sweep_backend("jax") == "jax"
    assert S.resolve_sweep_backend("pallas") == "pallas"
    # CPU-only container: auto falls back to numpy (conftest pins
    # JAX_PLATFORMS=cpu, so this is deterministic in tier-1)
    assert S.resolve_sweep_backend("auto") == "numpy"
    with pytest.raises(ValueError, match="backend"):
        S.resolve_sweep_backend("tpu-maxtext")


# -- end-to-end layer --------------------------------------------------------

_DISTS = [Exponential(1.0), ShiftedExponential(0.3, 1.2)]
_POLS = (PolicyCandidate("none"), PolicyCandidate("clone", 0.85),
         PolicyCandidate("hedged", hedge_fraction=0.3))
_KW = dict(n_workers=12, arrival_rate=0.8, n_jobs=200, seed=3,
           feasible_b=[2, 4])


def _dist_close(a, b, rtol=2e-2):
    """Distribution-level agreement: mean + tail quantiles per cell."""
    np.testing.assert_allclose(a.mean(axis=-1), b.mean(axis=-1), rtol=rtol)
    np.testing.assert_allclose(np.quantile(a, 0.9, axis=-1),
                               np.quantile(b, 0.9, axis=-1), rtol=rtol)


def test_policy_sweep_jax_matches_numpy():
    dists = _DISTS + [Empirical(np.random.default_rng(5).gamma(2.0, 0.5, 800))]
    r_np = S.sweep_sojourn_policies(dists, policies=_POLS, **_KW)
    r_jx = S.sweep_sojourn_policies(dists, policies=_POLS, backend="jax",
                                    **_KW)
    assert r_np.backend == "numpy" and r_jx.backend == "jax"
    _dist_close(r_np.samples, r_jx.samples)
    np.testing.assert_allclose(r_np.extra_fraction, r_jx.extra_fraction,
                               atol=2e-2)


def test_plain_and_speculative_sweep_jax_matches_numpy():
    s_np = S.sweep_sojourn(_DISTS, **_KW)
    s_jx = S.sweep_sojourn(_DISTS, backend="jax", **_KW)
    assert s_np.backend == "numpy" and s_jx.backend == "jax"
    _dist_close(s_np.samples, s_jx.samples)

    q_np = S.sweep_sojourn_speculative(_DISTS, quantiles=(None, 0.8), **_KW)
    q_jx = S.sweep_sojourn_speculative(_DISTS, quantiles=(None, 0.8),
                                       backend="jax", **_KW)
    _dist_close(q_np.samples, q_jx.samples)
    np.testing.assert_allclose(q_np.clone_fraction, q_jx.clone_fraction,
                               atol=2e-2)


def test_skewed_rates_policy_sweep_jax_matches_numpy():
    rates = np.linspace(0.5, 1.5, 12)
    r_np = S.sweep_sojourn_policies(_DISTS, policies=_POLS, rates=rates,
                                    **_KW)
    r_jx = S.sweep_sojourn_policies(_DISTS, policies=_POLS, rates=rates,
                                    backend="jax", **_KW)
    _dist_close(r_np.samples, r_jx.samples)


def test_worker_batches_thread_through_both_backends():
    """Custom placements (rate-aware assignments) reach the sweep on every
    backend; numpy vs jax agree on the batch-completion sweep exactly."""
    rng = np.random.default_rng(1)
    wbs = [rng.permutation(np.arange(12) % b) for b in (2, 4)]
    u_np = S.sweep_simulate(_DISTS, 12, n_trials=400, seed=1,
                            feasible_b=[2, 4], worker_batches=wbs)
    u_jx = S.sweep_simulate(_DISTS, 12, n_trials=400, seed=1,
                            feasible_b=[2, 4], worker_batches=wbs,
                            backend="jax")
    assert u_np.backend == "numpy" and u_jx.backend == "jax"
    np.testing.assert_allclose(u_np.samples, u_jx.samples, rtol=1e-5)

    with pytest.raises(ValueError, match="worker_batches"):
        S.sweep_simulate(_DISTS, 12, n_trials=50, feasible_b=[2, 4],
                         worker_batches=[np.zeros(12, int)])  # one per B


def test_backend_provenance_reaches_plan():
    """SweepSimResult/Plan record the RESOLVED engine, not the request —
    the PR-8 provenance fix ('auto' never leaks into results)."""
    res = S.sweep_simulate(_DISTS, 12, n_trials=200, feasible_b=[2, 4],
                           backend="auto")
    assert res.backend == "numpy"  # resolved on this CPU-only host

    spec = ClusterSpec(n_workers=12, dist=Exponential(1.0))
    obj = Objective(metric="mean")
    plan = make_planner("simulate", n_trials=500, backend="numpy").plan(
        spec, obj)
    assert plan.backend == "numpy"
    assert make_planner("analytic").plan(spec, obj).backend is None


# -- coded completion cells (PR 9) --------------------------------------------


@pytest.fixture(scope="module")
def coded_cell_batch():
    """(cells, trials, workers) service times + per-cell quorum sizes."""
    rng = np.random.default_rng(13)
    times = (rng.exponential(1.0, (5, 64, 12)) + 0.05).astype(np.float32)
    ks = np.array([1, 4, 8, 12, 6], np.int32)
    return times, ks


def test_coded_cells_all_backends_bit_match(coded_cell_batch):
    """f32 layer: the k-th-order-statistic selection is value-exact, so
    numpy reference, jit+vmap and the Pallas kernel must agree bit for bit
    at the same dtype."""
    times, ks = coded_cell_batch
    out_np = O.coded_completion_cells(times, ks, backend="numpy")
    out_jx = O.coded_completion_cells(times, ks, backend="jax")
    out_pl = O.coded_completion_cells(times, ks, backend="pallas")
    np.testing.assert_array_equal(out_np, np.asarray(out_jx))
    np.testing.assert_array_equal(np.asarray(out_jx), np.asarray(out_pl))


def test_coded_cells_match_sorted_selection(coded_cell_batch):
    """The reference IS the k-th smallest of each trial's worker times."""
    times, ks = coded_cell_batch
    out = O.coded_completion_cells(times, ks, backend="numpy")
    srt = np.sort(times, axis=-1)
    for c, k in enumerate(ks):
        np.testing.assert_array_equal(out[c], srt[c, :, int(k) - 1])


def test_sweep_coded_backends_agree_and_record_provenance():
    """End-to-end coded sweep: jax and pallas produce IDENTICAL samples
    (same traced body), numpy agrees to f32 tolerance, and each result
    carries the engine that actually ran."""
    from repro.core import CodingCandidate

    cands = (
        CodingCandidate("cyclic", 2, encode_overhead=0.0,
                        decode_overhead=0.0),
        CodingCandidate("mds", 6, encode_overhead=0.01,
                        decode_overhead=0.02),
    )
    kw = dict(n_trials=600, seed=5)
    r_np = S.sweep_coded(_DISTS, 12, cands, **kw)
    r_jx = S.sweep_coded(_DISTS, 12, cands, backend="jax", **kw)
    r_pl = S.sweep_coded(_DISTS, 12, cands, backend="pallas", **kw)
    assert (r_np.backend, r_jx.backend, r_pl.backend) == (
        "numpy", "jax", "pallas")
    np.testing.assert_array_equal(r_jx.samples, r_pl.samples)
    np.testing.assert_allclose(r_np.samples, r_jx.samples, rtol=1e-5)
    # measured overhead is ADDED to every sample of its candidate's cells
    zero = S.sweep_coded(_DISTS, 12, (cands[1].__class__(
        "mds", 6, encode_overhead=0.0, decode_overhead=0.0),), **kw)
    np.testing.assert_allclose(
        r_np.samples[:, 1], zero.samples[:, 0] + 0.03, rtol=1e-12)


def test_sweep_sojourn_coded_jax_matches_numpy():
    """Queueing-aware coded sweep shares the replication sweep's layered
    contract: numpy vs accelerated agree at distribution level."""
    from repro.core import CodingCandidate

    cands = (CodingCandidate("cyclic", 3, encode_overhead=0.0,
                             decode_overhead=0.0),
             CodingCandidate("mds", 8, encode_overhead=0.0,
                             decode_overhead=0.0))
    kw = dict(arrival_rate=0.6, n_jobs=300, seed=2)
    r_np = S.sweep_sojourn_coded(_DISTS, 12, cands, **kw)
    r_jx = S.sweep_sojourn_coded(_DISTS, 12, cands, backend="jax", **kw)
    assert r_np.backend == "numpy" and r_jx.backend == "jax"
    _dist_close(r_np.samples, r_jx.samples)


def test_tuner_replan_budget_waives_cooldown():
    """With replan_time_budget set and the measured plan() time under it,
    attempt pacing stops gating re-plans; the budget-less twin still
    backs off for the full cooldown."""
    def make(budget):
        cfg = TunerConfig(window_steps=50, min_samples=16,
                          cooldown_steps=1000, replan_time_budget=budget)
        return StragglerTuner(ReplicationPlan(n_data=8, n_batches=2), cfg)

    rng = np.random.default_rng(0)
    waived, paced = make(budget=60.0), make(budget=None)
    for _ in range(4):
        obs = rng.exponential(1.0, 8)
        waived.observe(obs)
        paced.observe(obs)
    waived.maybe_replan()
    paced.maybe_replan()
    assert waived.last_replan_seconds is not None
    assert waived.last_replan_seconds < 60.0
    first_attempt = waived._last_attempt
    obs = rng.exponential(1.0, 8)
    waived.observe(obs)
    paced.observe(obs)
    waived.maybe_replan()
    paced.maybe_replan()
    assert waived._last_attempt > first_attempt  # pacing waived: re-evaluated
    assert paced._last_attempt == first_attempt  # legacy cooldown still holds
