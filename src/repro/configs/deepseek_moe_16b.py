"""DeepSeekMoE-16B: fine-grained experts, 2 shared + 64 routed top-6,
first layer dense.

[arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base] 28L d_model=2048
16H (MHA) d_ff=1408(per expert) vocab=102400, MoE 64e top-6 + 2 shared.
Dense first-layer FFN width = 2 shared + 6 routed equivalents ~ 10944; we use
8 * d_expert = 11264 (8 expert-equivalents) for the dense layer, matching the
activated-expert budget.
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=11264,  # dense layer-0 FFN width (8 expert-equivalents)
    vocab_size=102400,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=10_000.0,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        first_layer_dense=True,
    ),
    subquadratic=False,
)
