"""Shard — the context object threading (mesh, policy) through model code.

Model code never touches jax.sharding directly; it calls semantic hooks:

* ``activation(x)``  — constrain a (b, s, d) residual-stream tensor
* ``full_seq(x)``    — force the seq dim gathered (pre-attention)
* ``cache(x)``       — constrain a (b, S_max, kv, hd) KV cache
* ``logits(x)``      — constrain (b, s, vocab)
* ``moe_buffer(x)``  — constrain (E, C, d) expert buffers

With ``mesh=None`` (CPU smoke tests) every hook is the identity, so the same
model code runs on one device with zero sharding machinery.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShardingPolicy

__all__ = ["Shard"]


@dataclasses.dataclass(frozen=True)
class Shard:
    mesh: Optional[Mesh]
    policy: ShardingPolicy

    @staticmethod
    def local(policy: Optional[ShardingPolicy] = None) -> "Shard":
        return Shard(mesh=None, policy=policy or ShardingPolicy())

    def _c(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def n_data_shards(self) -> int:
        """Extent of the data-parallel axes (1 on a local mesh)."""
        if self.mesh is None:
            return 1
        n = 1
        for a in self.policy.dp_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def dp(self):
        return self.policy.dp_axes

    @property
    def m(self):
        return self.policy.model_axis

    def activation(self, x):
        """(b, s, d): batch over dp; seq over model when sequence-parallel."""
        if self.policy.seq_shard:
            return self._c(x, P(self.dp, self.m, None))
        return self._c(x, P(self.dp, None, None))

    def full_seq(self, x):
        """(b, s, d) gathered over seq (entering attention)."""
        return self._c(x, P(self.dp, None, None))

    def mm_boundary(self, x):
        """Weight-matmul OUTPUT boundary under sequence parallelism.

        Primal: constrain to SEQ-SHARDED immediately (the partial-sum psum
        can lower as reduce-scatter — half the wire of an all-reduce).
        Cotangent: gather to FULL-SEQ before it reaches the matmul, so the
        weight-grad contraction is seq-local and never psums the (d, f)
        gradient over the model axis.  custom_vjp lets the two directions
        carry different shardings (§Perf iterations 4-7)."""
        if not (self.policy.seq_shard and self.policy.sp_weightgrad_fix):
            return x
        if self.mesh is None:
            return x

        shard = self

        @jax.custom_vjp
        def boundary(t):
            return t

        def fwd(t):
            return shard._c(t, P(shard.dp, shard.m, None)), None

        def bwd(_, g):
            return (shard._c(g, P(shard.dp, None, None)),)

        boundary.defvjp(fwd, bwd)
        return boundary(x)

    def mm_input(self, x):
        """Weight-matmul INPUT boundary: gather seq (fwd) so the forward
        weight contraction is seq-local; no-op when the fix is off."""
        if self.policy.seq_shard and self.policy.sp_weightgrad_fix:
            return self._c(x, P(self.dp, None, None))
        return x

    def heads(self, x):
        """(b, s, H, hd) q/k/v: heads over model (or head_dim per policy)."""
        if self.mesh is None:
            return x
        if self.policy.attn_mode == "heads":
            if x.shape[2] % self.mesh.shape[self.m]:
                return x  # unshardable head count (replicated small models)
            return self._c(x, P(self.dp, None, self.m, None))
        return self._c(x, P(self.dp, None, None, self.m))

    def cache(self, x):
        """(b, S_max, kv, hd) KV cache."""
        if self.policy.kv_seq_shard:
            return self._c(x, P(self.dp, self.m, None, None))
        if self.policy.shard_kv_heads:
            return self._c(x, P(self.dp, None, self.m, None))
        return self._c(x, P(self.dp, None, None, None))

    def cache_long(self, x):
        """(b, S_max, kv, hd) cache for batch=1 long-context: seq over dp."""
        return self._c(x, P(None, self.dp, self.m, None))

    def logits(self, x):
        v = self.m if self.policy.shard_vocab else None
        return self._c(x, P(self.dp, None, v))

    def moe_buffer(self, x):
        """(D, E, C, d) dispatched expert buffer: dp shards x experts."""
        return self._c(x, P(self.dp, self.m, None, None))

    def moe_tokens(self, x):
        """(D, T_local, d) tokens viewed as dp shards."""
        return self._c(x, P(self.dp, None, None))

    def ssm_state(self, x):
        """(b, h, n, p) SSM state: heads over model."""
        return self._c(x, P(self.dp, self.m, None, None))
