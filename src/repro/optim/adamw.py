"""AdamW with ZeRO-style sharded state and fp32 master weights.

Functional API (no optimizer classes):

    state = init(params, master_fp32=True)
    new_params, new_state = update(grads, state, params, lr, cfg)

State sharding: m/v/master inherit the PARAM PartitionSpecs via
:func:`state_specs` — with FSDP param specs that is full ZeRO-3; without
FSDP the states still shard over the model axis (ZeRO-1-ish on the TP
dimension).  Gradients arrive in fp32 (cast by the train step).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init", "update", "state_specs", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True


def init(params, cfg: AdamWConfig = AdamWConfig()):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def state_specs(param_specs, cfg: AdamWConfig = AdamWConfig()):
    from jax.sharding import PartitionSpec as P

    specs = {
        "step": P(),
        "m": param_specs,
        "v": param_specs,
    }
    if cfg.master_fp32:
        specs["master"] = param_specs
    return specs


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state, params, lr, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    ref = state["master"] if cfg.master_fp32 else params

    def upd(p32, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        return p32.astype(jnp.float32) - lr * (u + cfg.weight_decay * p32.astype(jnp.float32))

    new_master = jax.tree.map(upd, ref, m, v)
    new_params = jax.tree.map(
        lambda nm, p: nm.astype(p.dtype), new_master, params
    )
    new_state = {"step": step, "m": m, "v": v}
    if cfg.master_fp32:
        new_state["master"] = new_master
    return new_params, new_state, {"grad_norm": gnorm, "clip_scale": scale}
