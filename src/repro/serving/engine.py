"""Replicated serving engine — the paper's System1 as a discrete-event
request runtime.

Requests arrive under a configurable :mod:`~repro.serving.arrivals` process
(Poisson / MMPP-bursty / deterministic / replayed trace), queue at the
:class:`~repro.serving.queueing.EventDrivenMaster` (FIFO or priority
admission, batch formation under a max-wait + max-size policy), and each
formed batch is dispatched to a replica-set of r = N/B server groups — the
FASTEST replica's response completes the batch and the rest are cancelled
(the paper's rule).  A request's reported latency is its SOJOURN: queue
wait + service, the metric users actually feel under heavy traffic.

The engine

* actually executes prefill + decode on a (small) model for each completed
  batch (outputs are real tokens), driven off the event clock;
* draws per-replica service times from the calibrated straggler model;
* feeds the spectrum tuner three telemetry streams — per-replica service
  times (censored for cancelled replicas), the measured batch-formation
  rate, and per-request sojourns — so B adapts online through the
  load-aware ``ClusterSpec -> Plan`` control plane: re-plans are scored by
  simulated sojourn at the OBSERVED arrival rate and applied at a
  drain-then-swap quiesce point.

The lock-step API survives as a thin compatibility shim:
:meth:`ReplicatedServingEngine.serve_round` drives the event loop for one
synchronized round (every request pre-arrived, one pre-formed batch per
idle replica-set) and reproduces the legacy engine's latencies draw-for-draw
— while also fixing the legacy remainder bug (``n_requests % B != 0``
silently dropped the tail; see :func:`~repro.serving.queueing
.partition_requests`).
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Optional

import numpy as np

from repro.core import (
    ClusterSpec,
    CodingCandidate,
    Metric,
    Objective,
    PolicyCandidate,
    ReplicationPlan,
    ServiceDistribution,
    ShedPolicy,
    ShiftedExponential,
    SloClass,
    StragglerTuner,
    TunerConfig,
    make_planner,
)
from repro.serving.arrivals import ArrivalProcess, make_arrivals
from repro.serving.queueing import (
    BatchJob,
    ClonePolicy,
    EventDrivenMaster,
    HedgedDispatchPolicy,
    QueuePolicy,
    RelaunchPolicy,
    Request,
    StragglerPolicy,
    job_observations,
    partition_requests,
)

__all__ = ["ServeEngineConfig", "RequestStats", "ReplicatedServingEngine"]

_NO_TOKENS = np.empty(0, dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class ServeEngineConfig:
    arch: str = "qwen2-0.5b"
    n_server_groups: int = 8  # the paper's N
    n_batches: int = 4  # the paper's B (replication r = N/B)
    batch_size: int = 4  # requests per batch (queueing: max batch size)
    prompt_len: int = 16
    gen_tokens: int = 8
    max_len: int = 64
    # service-time model per REQUEST-UNIT of work (scaled by batch tokens)
    delta: float = 0.02
    mu: float = 50.0
    seed: int = 0
    # control plane: the ONE shared Metric literal + planner mode; B adapts
    # online through Planner.plan when ``tuner`` is on, and ``plan_initial``
    # lets the planner also pick the STARTING B from the ClusterSpec.
    # 'empirical' plans over bootstrap resamples of the observed service
    # times instead of a parametric fit (core.planner.EmpiricalPlanner).
    tuner: bool = False
    metric: Metric = "mean"
    planner_mode: str = "analytic"  # 'analytic' | 'simulate' | 'empirical'
    plan_initial: bool = False
    # sweep engine for simulation-capable planner modes: 'numpy', 'jax',
    # 'pallas', or 'auto' (accelerator when present, else numpy) — see
    # repro.core.simulator.SWEEP_BACKENDS.  'analytic' mode ignores it.
    sim_backend: str = "numpy"
    # wall-clock budget (seconds) for one re-plan: when the tuner measures
    # planner.plan() at or under this, re-plan cooldown pacing is waived
    # and hysteresis alone gates moves (TunerConfig.replan_time_budget).
    # Pair with an accelerator sim_backend; None keeps fixed cooldown.
    replan_time_budget: Optional[float] = None
    # goodness-of-fit gate: KS-test the parametric fit against the observed
    # service-time window at this significance; a rejected fit makes the
    # tuner re-plan through the empirical path for that attempt (None = off)
    gof_alpha: Optional[float] = None
    # --- discrete-event serving (arrival + queue knobs) ---------------------
    # offered load, either as REQUESTS per unit sim-time or as a fraction of
    # the fleet's no-replication capacity; either one makes the planner
    # objective load-aware (scored on sojourn, needs a simulation-capable
    # planner_mode: 'simulate' or 'empirical')
    # NOTE: the load-aware objective converts the REQUEST rate to a
    # batch-JOB rate as arrival_rate / batch_size, i.e. it assumes full
    # batches.  With a tight max_wait (or drop_expired) the master forms
    # partial batches and the true job rate is higher; the tuner's
    # observe_load telemetry corrects the estimate online when tuner=True.
    arrival_rate: Optional[float] = None
    utilization: Optional[float] = None
    arrival_kind: str = "poisson"  # 'poisson'|'mmpp'|'deterministic'|'trace'
    # recorded arrival offsets for arrival_kind='trace' (required there;
    # alternatively pass any ArrivalProcess straight to serve())
    arrival_offsets: Optional[tuple[float, ...]] = None
    max_wait: float = math.inf  # batch-formation deadline (sim-time units)
    queue_discipline: str = "fifo"  # 'fifo' | 'priority' | 'edf' | 'wfq'
    # --- multi-tenant SLO serving -------------------------------------------
    # tenant classes (core.SloClass): arrivals are labeled by class share,
    # per-class deadlines/weights drive EDF/WFQ and per-class miss
    # telemetry, and (with a 'simulate' planner) re-plans run the SERVING
    # sweep — every (B, policy, max_wait, shed) cell scored per request,
    # the winner's max_wait/shed adopted live.  Requires offered load
    # (arrival_rate or utilization).
    slo_classes: Optional[tuple[SloClass, ...]] = None
    # formation-deadline candidates for the serving sweep's max_wait axis
    # (default: just the config's max_wait)
    max_wait_candidates: Optional[tuple[float, ...]] = None
    # admission-control candidates for the serving sweep's shed axis
    # (core.ShedPolicy); the no-shed baseline is always raced alongside
    shed_candidates: Optional[tuple[ShedPolicy, ...]] = None
    # --- speculative re-dispatch (clone-attack straggler mitigation) --------
    # launch a clone of a batch onto an idle replica-set when its first
    # response is later than this quantile of the fitted min-over-replicas
    # service distribution (None = no speculation); clone_budget caps the
    # clones per batch job.  The same quantile seeds the planner objective,
    # so plan_initial / tuner re-plans score candidate B with speculation on.
    speculation_quantile: Optional[float] = None
    clone_budget: int = 1
    # which mitigation the live trigger drives: 'clone' copies a late batch
    # onto an idle set (original keeps running), 'relaunch' cancels the late
    # attempt and re-draws fresh on the same set, 'hedged' dispatches a
    # hedge_fraction of jobs to two sets up front (no trigger involved),
    # 'none' disables mitigation regardless of speculation_quantile
    straggler_policy: str = "clone"
    hedge_fraction: float = 1.0  # fraction of jobs hedged ('hedged' only)
    # adaptive portfolio: PolicyCandidate tuple the tuner's load-aware
    # re-plans score per candidate B; the winner lands on Plan.policy and
    # the engine adopts it live (the online policy-switch loop).  Overrides
    # the speculation_quantile-seeded trigger sweep in re-plan objectives.
    policy_candidates: Optional[tuple[PolicyCandidate, ...]] = None
    # coded-computation portfolio: CodingCandidate tuple every planner
    # objective (initial plan + tuner re-plans) races against the
    # replication sweep on shared CRN draws; a strict winner lands on
    # Plan.coding.  The event-driven master keeps serving replicated
    # batches — the coded pick is surfaced as telemetry/provenance (the
    # coded data plane lives in the cluster runtime), so this knob is the
    # control-plane view of the replication-vs-coding decision.  Needs a
    # simulation-capable planner_mode ('simulate' | 'empirical').
    coding_candidates: Optional[tuple[CodingCandidate, ...]] = None
    # --- deadlines / SLOs ---------------------------------------------------
    # uniform RELATIVE deadline applied to every request (arrival + deadline;
    # None = no SLO).  Per-request deadlines go through serve(deadlines=...).
    deadline: Optional[float] = None
    drop_expired: bool = False  # shed requests already past their deadline
    # observed miss rate above this waives re-plan hysteresis (None = off)
    miss_rate_target: Optional[float] = None
    # skip real prefill/decode (latency-only experiments, fast tests)
    execute_model: bool = True


@dataclasses.dataclass
class RequestStats:
    request_id: int
    arrival: float
    completion: float
    tokens: np.ndarray
    dispatched: float = math.nan
    deadline: float = math.inf  # absolute SLO deadline (inf = none)
    dropped: bool = False  # shed (drop-on-expiry / admission cap), never served
    slo: str = ""  # tenant class name ("" = untagged)

    @property
    def latency(self) -> float:
        """Sojourn: queue wait + service (== completion - arrival)."""
        return self.completion - self.arrival

    @property
    def queue_wait(self) -> float:
        return self.dispatched - self.arrival

    @property
    def service(self) -> float:
        return self.completion - self.dispatched

    @property
    def missed_deadline(self) -> bool:
        """True when a deadline-carrying request was late or dropped."""
        if not math.isfinite(self.deadline):
            return False
        return self.dropped or self.completion > self.deadline


class ReplicatedServingEngine:
    def __init__(self, sc: ServeEngineConfig):
        self.sc = sc
        self.dist: ServiceDistribution = ShiftedExponential(
            delta=sc.delta, mu=sc.mu
        )
        # the serving control plane hangs off ONE ClusterSpec + Planner
        self.cluster_spec = ClusterSpec(
            n_workers=sc.n_server_groups, dist=self.dist
        )
        # the LIVE straggler policy: starts at the config's, and adopts the
        # candidate chosen by each load-aware re-plan (which may be None —
        # the planner found plain replication better at the new B).  Set
        # before the objective/tuner: both are seeded from it.
        self.policy: Optional[PolicyCandidate] = self._initial_policy()
        # multi-tenant serving needs offered load (the per-request sweep is
        # load-aware by construction) and, for planning, the simulated
        # sweep — the analytic/empirical planners cannot score the
        # admission/WFQ/shedding model
        if sc.slo_classes:
            if sc.arrival_rate is None and sc.utilization is None:
                raise ValueError(
                    "slo_classes needs offered load: set ServeEngineConfig"
                    ".arrival_rate or .utilization"
                )
            if (sc.tuner or sc.plan_initial) and sc.planner_mode != "simulate":
                raise ValueError(
                    "slo_classes re-plans run the serving sweep; use "
                    "planner_mode='simulate'"
                )
            if sc.coding_candidates:
                raise ValueError(
                    "slo_classes and coding_candidates are mutually "
                    "exclusive: the serving sweep scores replication "
                    "policies only"
                )
        else:
            if sc.queue_discipline == "wfq":
                raise ValueError(
                    "queue_discipline='wfq' needs slo_classes (the class "
                    "weights are the WFQ shares)"
                )
            if sc.max_wait_candidates or sc.shed_candidates:
                raise ValueError(
                    "max_wait_candidates / shed_candidates only apply with "
                    "slo_classes"
                )
        # LIVE serving knobs: start at the config's, adopt each serving
        # re-plan's winning (max_wait, shed) cell — _queue_policy() reads
        # these, so the next formed master (and, via the reconfig/
        # swap_policy path, the running one) runs what the sweep scored
        self.max_wait: float = sc.max_wait
        self.shed: Optional[ShedPolicy] = None
        # job-arrival offsets for non-Poisson traffic, filled by
        # _build_objective and threaded into tuner re-plans (bugfix: sweeps
        # used to assume Poisson arrivals whatever the engine actually ran)
        self._job_arrival_offsets: Optional[tuple[float, ...]] = None
        self.objective = self._build_objective()
        # online re-plans re-score the whole sweep (sojourn-simulated when
        # the objective is load-aware), so size it like the tuner's default
        # sim budget rather than the offline 20k-trial analysis default
        self.planner = make_planner(
            mode=sc.planner_mode, n_trials=4_000, seed=sc.seed,
            backend=sc.sim_backend,
        )
        # the latest coded pick (Plan.coding) from any planner call: None
        # until a coding_candidates objective adopts a scheme; telemetry
        # provenance for run_load (the coded data plane is the cluster
        # runtime's job)
        self.last_coding: Optional[CodingCandidate] = None
        if sc.plan_initial:
            initial = self.planner.plan(self.cluster_spec, self.objective)
            n_batches = initial.n_batches
            self.last_coding = initial.coding
            if sc.slo_classes:
                # the serving plan decides policy/max_wait/shed too — run
                # from the start what the winning cell assumed
                self._adopt_serving(initial)
        else:
            n_batches = sc.n_batches
        self.plan = ReplicationPlan(
            n_data=sc.n_server_groups, n_batches=n_batches
        )
        self.rng = np.random.default_rng(sc.seed + 1)
        self._arrival_rng = np.random.default_rng(sc.seed + 2)
        # one observe() per completed batch: re-plan from >= 64 service
        # samples and at most every 16 batches — load-aware sweeps are
        # ~10^2 slower than the analytic closed form, and a fit from fewer
        # samples makes B oscillate under bursty formation telemetry
        self.tuner = StragglerTuner(
            self.plan,
            TunerConfig(
                window_steps=256, min_samples=64, cooldown_steps=16,
                # miss telemetry arrives one entry per resolved REQUEST
                # (served and dropped paths alike), so the window that
                # covers 256 batches of it is 256 x the batch size
                miss_window=256 * sc.batch_size,
                metric=sc.metric, miss_rate_target=sc.miss_rate_target,
                gof_alpha=sc.gof_alpha, sim_backend=sc.sim_backend,
                replan_time_budget=sc.replan_time_budget,
            ),
            planner=self.planner,
            job_load=self._work(sc.batch_size),
            # load-aware re-plans score candidate B with the SAME straggler
            # mitigation the master runs (else a fleet stable only because
            # it mitigates looks saturated and re-plans to no-replication):
            # an explicit portfolio when configured, a single-candidate
            # portfolio for relaunch/hedged, the legacy clone-trigger sweep
            # otherwise
            **self._tuner_decision_kwargs(),
            arrival_offsets=self._job_arrival_offsets,
        )
        self.clock = 0.0
        self._next_id = 0
        self.last_master: Optional[EventDrivenMaster] = None
        self._tokens: dict[int, np.ndarray] = {}
        self._formations: deque[float] = deque(maxlen=32)
        if sc.execute_model:
            import jax

            from repro.configs import get_config, reduced_config
            from repro.models import Shard, decode_step, init_params, prefill

            self.cfg = reduced_config(get_config(sc.arch))
            self.params = init_params(jax.random.PRNGKey(sc.seed), self.cfg)
            self.shard = Shard.local()
            self._prefill = prefill
            self._decode = jax.jit(
                lambda p, s, t, c: decode_step(self.cfg, self.shard, p, s, t, c)
            )
            self._prompt_key = jax.random.PRNGKey(sc.seed + 3)
        else:
            self.cfg = None
            self.params = None

    # -- straggler policy (live state) ---------------------------------------
    def _initial_policy(self) -> Optional[PolicyCandidate]:
        """The config's straggler mitigation as a PolicyCandidate (None =
        mitigation off)."""
        sc = self.sc
        if sc.straggler_policy not in ("none", "clone", "relaunch", "hedged"):
            raise ValueError(
                "ServeEngineConfig.straggler_policy must be 'none', "
                f"'clone', 'relaunch' or 'hedged', got {sc.straggler_policy!r}"
            )
        if sc.straggler_policy == "none":
            return None
        if sc.straggler_policy == "hedged":
            pol = PolicyCandidate("hedged", hedge_fraction=sc.hedge_fraction)
            return pol if pol.enabled else None
        if sc.speculation_quantile is None:
            return None  # trigger-driven kinds need a trigger
        return PolicyCandidate(
            sc.straggler_policy, quantile=sc.speculation_quantile
        )

    @property
    def speculation_quantile(self) -> Optional[float]:
        """The live CLONE trigger (legacy mirror — None whenever the live
        policy is anything other than a trigger-driven clone, same rule as
        ``Plan.speculation_quantile``)."""
        pol = self.policy
        if pol is not None and pol.kind == "clone":
            return pol.quantile
        return None

    @speculation_quantile.setter
    def speculation_quantile(self, q: Optional[float]) -> None:
        # legacy shim: assigning a trigger installs/uninstalls a clone policy
        self.policy = (
            PolicyCandidate("clone", quantile=float(q))
            if q is not None
            else None
        )

    def _trigger_quantile(self) -> Optional[float]:
        """The live policy's late trigger (clone OR relaunch; None = off)."""
        pol = self.policy
        if pol is not None and pol.kind in ("clone", "relaunch"):
            return pol.quantile
        return None

    def _adopt_policy(self, plan) -> None:
        """Run the mitigation the winning sweep score assumed — including
        'no mitigation at this B' (a disabled/None candidate)."""
        pol = plan.policy
        self.policy = pol if pol is not None and pol.enabled else None

    def _adopt_serving(self, plan) -> None:
        """Adopt a serving plan's FULL decision: mitigation policy plus the
        winning (max_wait, shed) cell."""
        self._adopt_policy(plan)
        if plan.max_wait is not None:
            self.max_wait = float(plan.max_wait)
        shed = plan.shed
        self.shed = shed if shed is not None and shed.kind != "none" else None

    def _tuner_decision_kwargs(self) -> dict:
        """Straggler-mitigation axis of tuner re-plan objectives (mirrors
        ``_build_objective``'s choice)."""
        sc = self.sc
        coding = (
            {"coding_candidates": tuple(sc.coding_candidates)}
            if sc.coding_candidates
            else {}
        )
        if sc.slo_classes:
            # serving sweep: the (max_wait, shed) axes ride along, and the
            # mitigation axis must be a portfolio (the serving sweep has no
            # legacy clone-trigger path) — the live policy becomes a
            # single-candidate portfolio when none is configured
            serving = {
                "slo_classes": tuple(sc.slo_classes),
                "serving_batch_size": sc.batch_size,
                "max_wait_candidates": (
                    tuple(sc.max_wait_candidates)
                    if sc.max_wait_candidates
                    else (sc.max_wait,)
                ),
                "shed_candidates": (
                    tuple(sc.shed_candidates) if sc.shed_candidates else None
                ),
            }
            if sc.policy_candidates:
                serving["policy_candidates"] = tuple(sc.policy_candidates)
            elif self.policy is not None:
                serving["policy_candidates"] = (self.policy,)
            return serving
        if sc.policy_candidates:
            return {"policy_candidates": tuple(sc.policy_candidates), **coding}
        pol = self.policy
        if pol is not None and pol.kind in ("relaunch", "hedged"):
            return {"policy_candidates": (pol,), **coding}
        return {
            "speculation_quantiles": (
                (pol.quantile,)
                if pol is not None and pol.kind == "clone"
                else None
            ),
            **coding,
        }

    # -- objective / arrivals ------------------------------------------------
    def _work(self, n_reqs: int) -> float:
        """Units of data one batch of ``n_reqs`` requests carries."""
        return n_reqs * (self.sc.prompt_len + self.sc.gen_tokens) / 100.0

    def _job_offsets_for(self, request_rate: float) -> Optional[tuple[float, ...]]:
        """Batch-JOB arrival offsets implied by a non-Poisson config.

        The load-aware sweeps default to Poisson job arrivals; when the
        engine runs MMPP/bursty/deterministic/trace traffic that default
        silently mis-scores every candidate (burstiness inflates queueing
        far beyond the Poisson prediction).  Sampling the configured
        process and keeping every ``batch_size``-th arrival (the instant a
        full batch forms) gives the sweep the job stream the master will
        actually see.  None for Poisson (the sweep's native default).
        """
        sc = self.sc
        if sc.arrival_kind == "poisson":
            return None
        if sc.arrival_kind == "trace":
            if sc.arrival_offsets is None:
                return None
            times = np.asarray(sc.arrival_offsets, dtype=float)
        else:
            proc = make_arrivals(sc.arrival_kind, rate=request_rate)
            # dedicated stream: must not perturb serve()'s arrival draws
            rng = np.random.default_rng((sc.seed, 0xA221))
            times = proc.sample(rng, 2_048 * sc.batch_size)
        jobs = times[sc.batch_size - 1 :: sc.batch_size]
        if jobs.size < 2:
            return None
        return tuple(float(t) for t in jobs)

    def _request_offsets_for(
        self, request_rate: float
    ) -> Optional[tuple[float, ...]]:
        """REQUEST arrival offsets implied by a non-Poisson config.

        The serving-sweep counterpart of :meth:`_job_offsets_for`: the
        multi-tenant scorer replays the per-request trace and forms its
        own batches, so no job collapsing happens here.  Short traces are
        cycled by the sweep (TraceArrivals replay rule).
        """
        sc = self.sc
        if sc.arrival_kind == "trace":
            if sc.arrival_offsets is None:
                return None
            times = np.asarray(sc.arrival_offsets, dtype=float)
        else:
            proc = make_arrivals(sc.arrival_kind, rate=request_rate)
            # dedicated stream: must not perturb serve()'s arrival draws
            rng = np.random.default_rng((sc.seed, 0xA222))
            times = proc.sample(rng, 2_048 * sc.batch_size)
        if times.size < 2:
            return None
        return tuple(float(t) for t in times)

    def _build_objective(self) -> Objective:
        sc = self.sc
        if sc.arrival_rate is not None and sc.utilization is not None:
            raise ValueError(
                "give ServeEngineConfig.arrival_rate OR .utilization, not "
                "both (same rule as Objective)"
            )
        load_aware = sc.arrival_rate is not None or sc.utilization is not None
        pol = self.policy
        policies: Optional[tuple[PolicyCandidate, ...]] = None
        spec_qs: Optional[tuple[float, ...]] = None
        if load_aware:
            # the planner scores candidate B under the SAME mitigation the
            # master runs: an explicit portfolio when configured, a single-
            # candidate portfolio for relaunch/hedged, the legacy clone-
            # trigger sweep otherwise
            if sc.policy_candidates:
                policies = tuple(sc.policy_candidates)
            elif pol is not None and pol.kind in ("relaunch", "hedged"):
                policies = (pol,)
            elif pol is not None and pol.kind == "clone":
                # the serving sweep has no legacy clone-trigger path: a live
                # clone policy rides as a single-candidate portfolio there
                if sc.slo_classes:
                    policies = (pol,)
                else:
                    spec_qs = (pol.quantile,)
        if sc.coding_candidates and sc.planner_mode == "analytic":
            raise ValueError(
                "coding_candidates needs a simulation-capable planner_mode "
                "('simulate' | 'empirical'): the closed-form planner cannot "
                "score coded candidates"
            )
        objective = Objective(
            metric=sc.metric,
            arrival_rate=(
                sc.arrival_rate / sc.batch_size
                if sc.arrival_rate is not None
                else None
            ),
            utilization=sc.utilization,
            job_load=self._work(sc.batch_size),
            speculation_quantiles=spec_qs,
            policies=policies,
            coding=(
                tuple(sc.coding_candidates) if sc.coding_candidates else None
            ),
        )
        if sc.slo_classes:
            objective = dataclasses.replace(
                objective,
                slo_classes=tuple(sc.slo_classes),
                batch_size=sc.batch_size,
                max_waits=(
                    tuple(sc.max_wait_candidates)
                    if sc.max_wait_candidates
                    else (sc.max_wait,)
                ),
                sheds=(
                    tuple(sc.shed_candidates) if sc.shed_candidates else None
                ),
            )
        if load_aware and sc.arrival_kind != "poisson":
            rate = (
                sc.arrival_rate
                if sc.arrival_rate is not None
                else objective.offered_rate(self.cluster_spec) * sc.batch_size
            )
            if sc.slo_classes:
                # the serving sweep is PER-REQUEST — it forms its own
                # batches per (max_wait, shed) cell — so it needs the raw
                # request trace.  Handing it the job-collapsed offsets
                # below would score every cell at 1/batch_size of the true
                # load, and B=1 "wins" the sweep of a fleet that is not
                # actually underloaded.  The default multitenant process is
                # Poisson-with-labels, exactly the sweep's internal
                # generator: attach nothing there, so tuner re-plans track
                # the OBSERVED rate instead of a trace pinned at build time.
                offs = (
                    None
                    if sc.arrival_kind == "multitenant"
                    else self._request_offsets_for(rate)
                )
            else:
                offs = self._job_offsets_for(rate)
            if offs is not None:
                self._job_arrival_offsets = offs
                objective = dataclasses.replace(objective, arrivals=offs)
        return objective

    def _request_rate(self) -> float:
        """Offered REQUEST arrival rate implied by the config."""
        sc = self.sc
        if sc.arrival_rate is not None:
            return sc.arrival_rate
        if sc.utilization is not None:
            return self.objective.offered_rate(self.cluster_spec) * sc.batch_size
        raise ValueError(
            "event-driven serving needs ServeEngineConfig.arrival_rate or "
            ".utilization (or pass an ArrivalProcess to serve())"
        )

    def _default_arrivals(self) -> ArrivalProcess:
        sc = self.sc
        if sc.arrival_kind == "trace":
            # a trace carries its own rate; the offsets are the config
            if sc.arrival_offsets is None:
                raise ValueError(
                    "arrival_kind='trace' needs ServeEngineConfig"
                    ".arrival_offsets (or pass an ArrivalProcess to serve())"
                )
            return make_arrivals(
                "trace", rate=1.0, offsets=sc.arrival_offsets
            )
        if sc.arrival_kind == "multitenant" and sc.slo_classes:
            # tenant shares come from the configured classes, so the
            # process's labels match the engine's class vocabulary
            return make_arrivals(
                "multitenant",
                rate=self._request_rate(),
                classes=tuple((c.name, c.share) for c in sc.slo_classes),
            )
        return make_arrivals(sc.arrival_kind, rate=self._request_rate())

    # -- real model work -----------------------------------------------------
    def _generate(self, prompts) -> np.ndarray:
        import jax.numpy as jnp

        sc = self.sc
        logits, state = self._prefill(
            self.cfg, self.shard, self.params, {"tokens": prompts},
            max_len=sc.max_len,
        )
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out = [tok]
        for i in range(sc.gen_tokens - 1):
            logits, state = self._decode(
                self.params, state, tok, jnp.int32(sc.prompt_len + i)
            )
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))

    def _generate_for_job(self, job: BatchJob) -> None:
        """Run real prefill+decode for a completed batch (event path).

        Prompts are keyed by request id (fold_in), so WHAT is generated for a
        request is invariant to how traffic got batched or replicated.
        """
        import jax

        sc = self.sc
        rows = [
            jax.random.randint(
                jax.random.fold_in(self._prompt_key, req.request_id),
                (sc.prompt_len,), 0, self.cfg.vocab_size,
            )
            for req in job.requests
        ]
        tokens = self._generate(jax.numpy.stack(rows))
        for k, req in enumerate(job.requests):
            self._tokens[req.request_id] = tokens[k]

    # -- event-driven serving ------------------------------------------------
    def _service_sampler(self, job: BatchJob, group: int) -> np.ndarray:
        """Per-replica service draws for one dispatched batch."""
        work = self._work(job.size)
        return self.dist.scaled(work).sample(self.rng, self.plan.replication)

    def _speculation_threshold(self, job: BatchJob) -> float:
        """Late-quantile of the calibrated FIRST-RESPONSE distribution.

        The first response of a batch is the min over its r replicas'
        service draws; for the (shifted-)exponential straggler model that
        min keeps the shift and multiplies the rate by r, so its q-quantile
        is ``shift + -ln(1-q) / (r * mu)``.  A response later than this is
        late with model probability 1 - q — the clone/relaunch trigger.
        Reads the LIVE policy/plan, so a mid-run re-plan that changed B or
        disabled mitigation (inf threshold) takes effect on the next
        dispatch.
        """
        q = self._trigger_quantile()
        if q is None:
            return math.inf  # re-plan disabled mitigation mid-run
        scaled = self.dist.scaled(self._work(job.size))
        r = max(self.plan.replication, 1)
        shift = float(getattr(scaled, "delta", 0.0))
        return shift + (-math.log1p(-q)) / (scaled.mu * r)

    def _speculation_policy(self) -> Optional[StragglerPolicy]:
        """The master's straggler policy implied by the live candidate
        (None = mitigation off)."""
        pol = self.policy
        if pol is None or not pol.enabled:
            return None
        if pol.kind == "clone":
            return ClonePolicy(
                late_quantile=pol.quantile,
                max_clones=self.sc.clone_budget,
                threshold=self._speculation_threshold,
            )
        if pol.kind == "relaunch":
            return RelaunchPolicy(
                late_quantile=pol.quantile,
                max_relaunches=self.sc.clone_budget,
                threshold=self._speculation_threshold,
            )
        return HedgedDispatchPolicy(k=2, hedge_fraction=pol.hedge_fraction)

    def _queue_policy(self) -> QueuePolicy:
        """The master's queue policy from the LIVE serving state: config
        discipline + adopted ``max_wait`` + adopted shed policy ('expired'
        -> drop-on-expiry, 'cap' -> admission queue cap)."""
        sc = self.sc
        shed = self.shed
        return QueuePolicy(
            max_batch_size=sc.batch_size,
            max_wait=self.max_wait,
            discipline=sc.queue_discipline,
            drop_expired=(
                sc.drop_expired or (shed is not None and shed.kind == "expired")
            ),
            queue_cap=(
                shed.cap if shed is not None and shed.kind == "cap" else None
            ),
            class_weights=(
                tuple((c.name, c.weight) for c in sc.slo_classes)
                if sc.slo_classes and sc.queue_discipline == "wfq"
                else None
            ),
        )

    def _on_drop(self, req: Request) -> None:
        """Stream a shed request into the tuner AS IT HAPPENS (a drop-heavy
        SLO breach can then trigger a re-plan mid-stream).  PER-REQUEST and
        class-attributed, the same granularity as the served path — and
        only deadline-carrying requests count (a cap-shed of a best-effort
        request is lost work, not a deadline miss)."""
        if math.isfinite(req.deadline):
            self.tuner.observe_deadline_misses(1, 1, slo=req.slo)

    def _on_job_complete(self, job: BatchJob) -> Optional[dict]:
        """Telemetry + model work + (maybe) a drain-then-swap re-plan."""
        work = self._work(job.size)
        # censoring-correct per-replica telemetry across the live attempt,
        # relaunch-discarded attempts, and clones/hedges — shared with the
        # wall-clock cluster coordinator (queueing.job_observations)
        for times, censored in job_observations(job):
            self.tuner.observe(times / work, censored=censored)
        self.tuner.observe_sojourn(
            np.array([req.sojourn for req in job.requests])
        )
        # PER-REQUEST miss accounting, matching the drop path's granularity
        # (a batch-level (n_missed, n_batch) observation would weight each
        # batch equally however many requests it resolved — partial batches
        # then skew the windowed rate) and carrying the SLO class so
        # per-class breach detection sees served outcomes too
        for req in job.requests:
            if math.isfinite(req.deadline):
                self.tuner.observe_deadline_misses(
                    int(req.completion > req.deadline), 1, slo=req.slo
                )
        self._formations.append(job.formed_at)
        if len(self._formations) >= 2:
            # jobs complete out of formation order (slow sets finish late),
            # so span the window by max-min, not last-first
            span = max(self._formations) - min(self._formations)
            if span > 0:
                self.tuner.observe_load((len(self._formations) - 1) / span)
        if self.sc.execute_model:
            self._generate_for_job(job)
        if self.sc.tuner:
            rp = self.tuner.maybe_replan()
            if rp is not None:
                self.plan = self.tuner.apply(rp)
                # adopt the mitigation the winning score assumed: when the
                # re-plan swept (B, policy) or (B, trigger) cells, run what
                # it scored — including "don't mitigate at this B" (None)
                if rp.plan is not None and rp.plan.objective.coding:
                    self.last_coding = rp.plan.coding
                if rp.plan is not None and rp.plan.objective.slo_classes:
                    # serving re-plan: adopt the whole (policy, max_wait,
                    # shed) cell and ship the new queue policy to the
                    # quiesce point alongside the new fabric
                    self._adopt_serving(rp.plan)
                    return {
                        "n_groups": self.plan.n_batches,
                        "policy": self._queue_policy(),
                    }
                if rp.plan is not None and rp.plan.objective.policies:
                    self._adopt_policy(rp.plan)
                elif (
                    rp.plan is not None
                    and rp.plan.objective.speculation_quantiles
                ):
                    self.speculation_quantile = rp.plan.speculation_quantile
                return {"n_groups": self.plan.n_batches}
            # no B move, but the last evaluated sweep may still have found
            # a better policy/trigger AT the current B — adopting it needs
            # no drain/reconfig, so it is free (cooldown paces evaluations)
            lp = self.tuner.last_plan
            if lp is not None and lp.objective.coding:
                self.last_coding = lp.coding
            if lp is not None and lp.n_batches == self.plan.n_batches:
                if lp.objective.slo_classes:
                    self._adopt_serving(lp)
                    # same-B adoption needs no drain: max_wait/cap are
                    # scalar knobs the live master swaps in place
                    if self.last_master is not None:
                        self.last_master.swap_policy(self._queue_policy())
                elif lp.objective.policies:
                    self._adopt_policy(lp)
                elif lp.objective.speculation_quantiles:
                    self.speculation_quantile = lp.speculation_quantile
        return None

    def serve(
        self,
        n_requests: int,
        arrivals: Optional[ArrivalProcess] = None,
        deadlines: Optional[np.ndarray] = None,
        priorities: Optional[np.ndarray] = None,
    ) -> list[RequestStats]:
        """Serve ``n_requests`` arriving under ``arrivals`` (default: the
        config's process at the configured offered load) through the
        event-driven master; returns per-request sojourn stats.

        ``deadlines`` (per-request, RELATIVE to arrival) overrides the
        config's uniform ``deadline``; ``priorities`` feeds the
        ``'priority'`` discipline.  Requests carrying deadlines drive EDF
        ordering, drop-on-expiry, and deadline-miss telemetry.

        With ``slo_classes`` every arrival is labeled with a tenant class —
        by the arrival process itself when it can
        (:meth:`~repro.serving.arrivals.MultiTenantArrivals
        .sample_with_classes`), else by an independent share draw — and the
        class deadline applies where neither ``deadlines`` nor the config's
        uniform ``deadline`` does.
        """
        sc = self.sc
        process = arrivals if arrivals is not None else self._default_arrivals()
        labels: Optional[list[str]] = None
        if sc.slo_classes and hasattr(process, "sample_with_classes"):
            times, labels = process.sample_with_classes(
                self._arrival_rng, n_requests, start=self.clock
            )
        else:
            times = process.sample(
                self._arrival_rng, n_requests, start=self.clock
            )
            if sc.slo_classes:
                shares = np.array(
                    [c.share for c in sc.slo_classes], dtype=float
                )
                idx = self._arrival_rng.choice(
                    len(shares), size=n_requests, p=shares / shares.sum()
                )
                labels = [sc.slo_classes[i].name for i in idx]
        if deadlines is None and sc.deadline is not None:
            deadlines = np.full(n_requests, sc.deadline)
        if deadlines is not None and len(deadlines) != n_requests:
            raise ValueError(
                f"deadlines length {len(deadlines)} != {n_requests}"
            )
        if priorities is not None and len(priorities) != n_requests:
            raise ValueError(
                f"priorities length {len(priorities)} != {n_requests}"
            )
        class_deadline = (
            {c.name: c.deadline for c in sc.slo_classes}
            if sc.slo_classes
            else {}
        )

        def _deadline(i: int, t: float) -> float:
            if deadlines is not None:
                return t + float(deadlines[i])
            if labels is not None:
                rel = class_deadline.get(labels[i])
                if rel is not None:
                    return t + float(rel)
            return math.inf

        requests = [
            Request(
                request_id=self._next_id + i,
                arrival=float(t),
                deadline=_deadline(i, float(t)),
                priority=(
                    float(priorities[i]) if priorities is not None else 0.0
                ),
                slo=labels[i] if labels is not None else "",
            )
            for i, t in enumerate(times)
        ]
        self._next_id += n_requests
        master = EventDrivenMaster(
            n_groups=self.plan.n_batches,
            service_sampler=self._service_sampler,
            policy=self._queue_policy(),
            clock=self.clock,
            on_job_complete=self._on_job_complete,
            speculation=self._speculation_policy(),
            # a dropped request resolved as a miss without reaching any job
            # callback: stream it into the tuner AS IT HAPPENS, per request
            # and class-attributed (see _on_drop)
            on_drop=self._on_drop,
        )
        self._tokens = {}
        # visible to _on_job_complete DURING the run: same-B serving
        # re-plans swap the live master's queue policy in place
        self.last_master = master
        for req in requests:
            master.submit(req)
        master.run()
        self.clock = master.clock
        return [
            RequestStats(
                request_id=req.request_id,
                arrival=req.arrival,
                completion=req.completion,
                tokens=self._tokens.get(req.request_id, _NO_TOKENS),
                dispatched=req.dispatched,
                deadline=req.deadline,
                dropped=req.dropped,
                slo=req.slo,
            )
            for req in requests
        ]

    def run_load(
        self,
        n_requests: int = 512,
        arrivals: Optional[ArrivalProcess] = None,
        deadlines: Optional[np.ndarray] = None,
    ) -> dict:
        """Event-driven driver: serve a request stream, report sojourn
        quantiles plus SLO/speculation telemetry (the serving twin of
        :meth:`run`).  Sojourn quantiles cover SERVED requests only;
        ``deadline_miss_rate`` covers every deadline-carrying request
        (dropped ones count as misses) and is None when no request carried
        a deadline.  With ``slo_classes``, ``class_stats`` breaks request
        counts, drops, miss rates, and sojourns down per tenant class."""
        start = self.clock
        stats = self.serve(n_requests, arrivals, deadlines=deadlines)
        served = [s for s in stats if not s.dropped]
        soj = np.array([s.latency for s in served])
        wait = np.array([s.queue_wait for s in served])
        with_deadline = [s for s in stats if math.isfinite(s.deadline)]
        miss_rate = (
            sum(s.missed_deadline for s in with_deadline) / len(with_deadline)
            if with_deadline
            else None
        )
        class_stats: Optional[dict] = None
        if self.sc.slo_classes:
            class_stats = {}
            for c in self.sc.slo_classes:
                cls = [s for s in stats if s.slo == c.name]
                cls_served = [s for s in cls if not s.dropped]
                cls_dl = [s for s in cls if math.isfinite(s.deadline)]
                cls_soj = np.array([s.latency for s in cls_served])
                class_stats[c.name] = {
                    "requests": len(cls),
                    "served": len(cls_served),
                    "dropped": len(cls) - len(cls_served),
                    "miss_rate": (
                        sum(s.missed_deadline for s in cls_dl) / len(cls_dl)
                        if cls_dl
                        else None
                    ),
                    "mean_sojourn": (
                        float(cls_soj.mean()) if len(cls_served) else math.nan
                    ),
                    "p99_sojourn": (
                        float(np.quantile(cls_soj, 0.99))
                        if len(cls_served)
                        else math.nan
                    ),
                }
        return {
            "requests": len(stats),
            "mean_sojourn": float(soj.mean()) if len(served) else math.nan,
            "p50_sojourn": (
                float(np.quantile(soj, 0.50)) if len(served) else math.nan
            ),
            "p99_sojourn": (
                float(np.quantile(soj, 0.99)) if len(served) else math.nan
            ),
            "p999_sojourn": (
                float(np.quantile(soj, 0.999)) if len(served) else math.nan
            ),
            "mean_queue_wait": (
                float(wait.mean()) if len(served) else math.nan
            ),
            "throughput": len(served) / max(self.clock - start, 1e-9),
            "final_B": self.plan.n_batches,
            "deadline_miss_rate": miss_rate,
            "n_dropped": len(stats) - len(served),
            "speculations": (
                self.last_master.speculations if self.last_master else 0
            ),
            "relaunches": (
                self.last_master.relaunches if self.last_master else 0
            ),
            "hedges": self.last_master.hedges if self.last_master else 0,
            "policy": self.policy.kind if self.policy is not None else "none",
            "max_wait": self.max_wait,
            "shed": self.shed.kind if self.shed is not None else "none",
            "class_stats": class_stats,
            "coding": (
                self.last_coding.describe()
                if self.last_coding is not None
                else "none"
            ),
            "stats": stats,
        }

    # -- one master round (compatibility shim) -------------------------------
    def serve_round(self, n_requests: Optional[int] = None) -> list[RequestStats]:
        """One SYNCHRONIZED round through the event loop (legacy API).

        Accept B*batch_size requests (default), all arriving at the current
        clock; one pre-formed batch per idle replica-set with service times
        pre-drawn in the legacy engine's RNG order — so zero-queueing
        latencies reproduce the lock-step engine draw-for-draw.  Unlike the
        legacy engine, the LAST batch absorbs the ``n_requests % B``
        remainder instead of silently dropping it.
        """
        sc = self.sc
        b = self.plan.n_batches
        r = self.plan.replication
        n_requests = n_requests or b * sc.batch_size
        arrival = self.clock

        if sc.execute_model:
            import jax

            prompts = jax.random.randint(
                jax.random.PRNGKey(sc.seed + self._next_id),
                (n_requests, sc.prompt_len), 0, self.cfg.vocab_size,
            )
        # batching unit: contiguous request slices (legacy layout, remainder
        # riding with the last batch); service times in the legacy RNG order
        per_batch = max(n_requests // b, 1)
        work = self._work(per_batch)
        times = self.dist.scaled(work).sample(self.rng, (b, r))
        slices = partition_requests(n_requests, b)
        # Exp/SExp scale affinely with load, so rescaling a row re-prices a
        # batch for its TRUE size from the same draws: the remainder-absorbing
        # last batch is charged its real work, while every equal-size row is
        # multiplied by exactly 1.0 (bit-for-bit with the legacy engine)
        row_work = np.array([
            self._work(hi - lo) if hi > lo else work for lo, hi in slices
        ])
        times = times * (row_work / work)[:, None]

        master = EventDrivenMaster(
            n_groups=b,
            service_sampler=self._service_sampler,
            clock=arrival,
        )
        jobs: list[tuple[int, BatchJob]] = []
        for bi, (lo, hi) in enumerate(slices):
            if lo >= hi:
                continue
            reqs = [
                Request(request_id=self._next_id + k, arrival=arrival)
                for k in range(lo, hi)
            ]
            jobs.append(
                (bi, master.submit_formed(reqs, at=arrival, service_times=times[bi]))
            )
        master.run()
        self._next_id += n_requests

        stats: list[RequestStats] = []
        for bi, job in jobs:
            lo, hi = slices[bi]
            tokens = self._generate(prompts[lo:hi]) if sc.execute_model else None
            for k, req in enumerate(job.requests):
                stats.append(
                    RequestStats(
                        request_id=req.request_id,
                        arrival=req.arrival,
                        completion=req.completion,
                        tokens=(
                            tokens[k] if tokens is not None else _NO_TOKENS
                        ),
                        dispatched=req.dispatched,
                    )
                )
        # legacy round clock: max over ALL replica-set minima, including
        # sets whose slice was empty (n_requests < B)
        self.clock = arrival + float(times.min(axis=1).max())
        # telemetry: per-unit times (normalized by each row's true work),
        # censored AT THE CANCELLATION TIME for unused replicas
        # (first-replica-wins cancels them at the batch minimum; their full
        # draws were never observable)
        batch_done = times.min(axis=1)
        observed = np.minimum(times, batch_done[:, None])
        used = np.zeros_like(times, dtype=bool)
        used[np.arange(b), times.argmin(axis=1)] = True
        self.tuner.observe(
            (observed / row_work[:, None]).reshape(-1),
            censored=~used.reshape(-1),
        )
        if self.sc.tuner:
            rp = self.tuner.maybe_replan()
            if rp is not None:
                self.plan = self.tuner.apply(rp)
        return stats

    def run(self, n_rounds: int = 5) -> dict:
        all_stats: list[RequestStats] = []
        for _ in range(n_rounds):
            all_stats.extend(self.serve_round())
        lat = np.array([s.latency for s in all_stats])
        return {
            "requests": len(all_stats),
            "mean_latency": float(lat.mean()),
            "p99_latency": float(np.quantile(lat, 0.99)),
            "throughput": len(all_stats) / max(self.clock, 1e-9),
            "final_B": self.plan.n_batches,
            "stats": all_stats,
        }
