"""Online diversity–parallelism tuner: observe -> fit -> ``Planner.plan``.

Closes the loop the paper leaves open: *where do Delta and mu come from?*
The tuner ingests per-step, per-worker service times (censored when the step
completed before slow workers finished), maintains a sliding window, fits the
service distribution (core.estimator), and estimates per-worker rates.  The
actual B decision is NOT made here: the tuner assembles a
:class:`~repro.core.planner.ClusterSpec` from its window and delegates to a
:class:`~repro.core.planner.Planner` — analytic, simulated, or heterogeneous
(see :func:`~repro.core.planner.make_planner`).  A re-plan is emitted only
when the predicted improvement clears the Objective's hysteresis threshold
and a cooldown has elapsed — re-factoring the mesh is not free (it flushes
compiled executables and reshuffles the data pipeline), so we only move for
real wins.  With an accelerator-resident sweep backend
(``TunerConfig.sim_backend='auto'|'jax'|'pallas'``) the sweep itself stops
being the bottleneck: set ``TunerConfig.replan_time_budget`` and the
cooldown pacing is waived whenever the measured re-plan time
(:attr:`StragglerTuner.last_replan_seconds`) comes in under budget —
hysteresis alone then decides when to move.

Serving feeds three extra telemetry streams: :meth:`StragglerTuner
.observe_load` (measured batch-job arrival rate), :meth:`StragglerTuner
.observe_sojourn` (per-request queue wait + service), and
:meth:`StragglerTuner.observe_deadline_misses` (SLO outcomes of requests
carrying deadlines).  With a load-capable planner the re-plan Objective then
carries the observed arrival rate — candidate B is scored by simulated
sojourn quantiles — and hysteresis measures the predicted win against the
sojourn requests ACTUALLY experienced at the current B.  A breached
``TunerConfig.miss_rate_target`` waives the hysteresis threshold: when the
fleet is missing its SLO, any predicted improvement justifies the move.

**Goodness-of-fit gate.**  The parametric fit is only as good as the
assumption that the fleet is Exp/SExp-shaped.  With ``TunerConfig.gof_alpha``
set, every re-plan attempt first checks the fitted distribution against the
observation window (censoring-aware KS, :func:`~repro.core.estimator
.goodness_of_fit`); a REJECTED fit reroutes that re-plan through the
empirical path automatically — the window becomes an
:class:`~repro.core.order_stats.Empirical` distribution (Kaplan-Meier, so
censored replicas still count) and an
:class:`~repro.core.planner.EmpiricalPlanner` plans over bootstrap
resamples of it.  ``TunerConfig(mode='empirical')`` makes that path the
primary planner instead of the fallback.
"""

from __future__ import annotations

import dataclasses
import math
import time
import warnings
from collections import deque
from typing import Literal, Optional

import numpy as np

from .estimator import FitResult, GofResult, fit_best, goodness_of_fit
from .order_stats import Empirical
from .planner import (
    ClusterSpec,
    Objective,
    Plan,
    Planner,
    make_planner,
)
from .replication import ReplicationPlan
from .spectrum import Metric

__all__ = ["TunerConfig", "RescalePlan", "StragglerTuner"]


@dataclasses.dataclass(frozen=True)
class TunerConfig:
    window_steps: int = 50  # sliding window of step observations
    min_samples: int = 64  # don't fit with fewer points
    improvement_threshold: float = 0.10  # >=10% predicted win to move
    cooldown_steps: int = 20  # steps between re-plans
    metric: Metric = "mean"  # the ONE shared Metric literal (incl. p999)
    # "analytic": closed-form sweep (homogeneous Exp/SExp only).
    # "simulate": one batched sweep_simulate call, optionally with the
    # per-worker rate estimates from the observation window (heterogeneous).
    # "empirical": bootstrap-resample the observation window itself
    # (EmpiricalPlanner) — no parametric family assumed at all.
    mode: Literal["analytic", "simulate", "empirical"] = "analytic"
    heterogeneous: bool = False  # feed worker_rates() into the simulated sweep
    sim_trials: int = 4_000
    # sweep engine for simulated/empirical planners: "numpy", "jax",
    # "pallas", or "auto" (accelerator when present, numpy otherwise) —
    # see repro.core.simulator.SWEEP_BACKENDS
    sim_backend: str = "numpy"
    sim_seed: int = 0
    # wall-clock budget (seconds) for one full re-plan.  The cooldown
    # exists to amortize EXPENSIVE sweeps; with an accelerator-resident
    # backend a full re-plan is sub-second, and rate-limiting it only
    # delays reactions to drift.  When set, any attempt whose measured
    # plan() time came in at or under this budget stops counting against
    # the cooldown pacing — re-plans are then gated by hysteresis alone.
    # None keeps the legacy fixed-cooldown behavior.
    replan_time_budget: Optional[float] = None
    # SLO trigger: when the observed deadline-miss rate exceeds this target,
    # the hysteresis threshold is waived for the next re-plan (None = off)
    miss_rate_target: Optional[float] = None
    # sliding-window size, in REQUESTS, for deadline-miss telemetry.
    # observe_deadline_misses feeds ONE entry per resolved request (served
    # or dropped), so the window that matches window_steps batches of
    # telemetry is window_steps x the serving batch size — the engine sets
    # exactly that.  None = window_steps entries (legacy).
    miss_window: Optional[int] = None
    # goodness-of-fit gate: when set, each re-plan attempt KS-tests the
    # parametric fit against the observation window (censoring-aware) at
    # this significance level; a rejected fit reroutes THAT re-plan through
    # the empirical path (EmpiricalPlanner over the window's Kaplan-Meier
    # ECDF).  None = gate off (always trust the parametric fit).
    gof_alpha: Optional[float] = None
    # bootstrap resamples for the empirical path (primary or gate fallback)
    bootstrap_resamples: int = 20

    def objective(self) -> Objective:
        """The planner Objective this config describes."""
        return Objective(
            metric=self.metric,
            improvement_threshold=self.improvement_threshold,
            cooldown_steps=self.cooldown_steps,
        )

    def planner(self) -> Planner:
        """The Planner this config describes (legacy-knob mapping).

        ``heterogeneous=True`` with the default ``mode='analytic'`` was
        legal-but-inert before the planner API; the legacy mapping keeps
        that behavior (warn + ignore the flag) where the strict
        :func:`make_planner` would raise.
        """
        heterogeneous = self.heterogeneous
        if self.mode == "analytic" and heterogeneous:
            warnings.warn(
                "TunerConfig(heterogeneous=True) has no effect with "
                "mode='analytic'; use mode='simulate' for rate-aware "
                "re-plans",
                DeprecationWarning,
                stacklevel=2,
            )
            heterogeneous = False
        return make_planner(
            mode=self.mode,
            heterogeneous=heterogeneous,
            n_trials=self.sim_trials,
            seed=self.sim_seed,
            backend=self.sim_backend,
            n_resamples=self.bootstrap_resamples,
        )


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    old_batches: int
    new_batches: int
    predicted_old: float
    predicted_new: float
    fit: FitResult
    step: int
    plan: Optional[Plan] = None  # the full planner decision (assignment etc.)

    @property
    def predicted_improvement(self) -> float:
        if self.predicted_old <= 0:
            return 0.0
        return 1.0 - self.predicted_new / self.predicted_old


class StragglerTuner:
    """Observe-window + re-plan trigger around a :class:`Planner`."""

    # verdict of the goodness-of-fit gate at the last re-plan attempt (None
    # while the gate is off or before the first attempt); class-level default
    # so the attribute is part of the documented API surface
    last_gof: Optional[GofResult] = None
    # measured wall-clock seconds of the last planner.plan() call (None
    # before the first attempt).  This is what TunerConfig
    # .replan_time_budget compares against to decide whether cooldown
    # pacing is still buying anything.
    last_replan_seconds: Optional[float] = None

    def __init__(
        self,
        plan: ReplicationPlan,
        config: TunerConfig | None = None,
        planner: Planner | None = None,
        batch_divisor: int | None = None,
        job_load: float = 1.0,
        speculation_quantiles: tuple[float, ...] | None = None,
        policy_candidates: tuple | None = None,
        arrival_offsets: np.ndarray | None = None,
        coding_candidates: tuple | None = None,
        slo_classes: tuple | None = None,
        serving_batch_size: int | None = None,
        max_wait_candidates: tuple[float, ...] | None = None,
        shed_candidates: tuple | None = None,
    ):
        self.plan = plan
        self.config = config or TunerConfig()
        self.planner = planner if planner is not None else self.config.planner()
        # extra feasibility constraint carried into every ClusterSpec: B must
        # divide this (e.g. the global batch size, so re-plans never pick a B
        # the data pipeline cannot shard)
        self.batch_divisor = batch_divisor
        # units of data one batch-job carries (serving: batch tokens / unit);
        # scales the load-aware objective's service model
        self.job_load = job_load
        # clone triggers the serving master is running: load-aware re-plans
        # must score candidate B WITH speculation, else a fleet that is only
        # stable because it speculates looks saturated to the planner
        self.speculation_quantiles = (
            tuple(float(q) for q in speculation_quantiles)
            if speculation_quantiles
            else None
        )
        # straggler-policy portfolio: when set, load-aware re-plans score
        # every (B, candidate) cell and land the winner on Plan.policy —
        # this is how the tuner switches policy online when the fitted /
        # empirical distribution drifts across a regime boundary.
        # Mutually exclusive with speculation_quantiles (Objective enforces).
        self.policy_candidates = (
            tuple(policy_candidates) if policy_candidates else None
        )
        if self.policy_candidates and self.speculation_quantiles:
            raise ValueError(
                "policy_candidates and speculation_quantiles are mutually "
                "exclusive: the portfolio subsumes the clone-trigger sweep "
                "(use PolicyCandidate('clone', quantile=q) candidates)"
            )
        # coded-computation portfolio: when set, every re-plan races the
        # listed CodingCandidates (cyclic / MDS / poly, measured overheads)
        # against the replication sweep on shared CRN draws and lands a
        # strict winner on Plan.coding — both batch-completion and
        # load-aware objectives, simulated planners only.
        self.coding_candidates = (
            tuple(coding_candidates) if coding_candidates else None
        )
        # multi-tenant serving: when set, load-aware re-plans run the
        # SERVING sweep (per-request admission/WFQ/shedding model) instead
        # of the job-level sojourn sweep — every (B, policy, max_wait,
        # shed) cell scored on shared CRN draws, winner landing on
        # Plan.max_wait / Plan.shed / Plan.class_report.  Requires the
        # serving batch size (Objective.request_rate needs it to convert
        # the observed JOB arrival rate back to a request rate).
        self.slo_classes = tuple(slo_classes) if slo_classes else None
        self.serving_batch_size = (
            int(serving_batch_size) if serving_batch_size is not None else None
        )
        self.max_wait_candidates = (
            tuple(float(w) for w in max_wait_candidates)
            if max_wait_candidates
            else None
        )
        self.shed_candidates = (
            tuple(shed_candidates) if shed_candidates else None
        )
        if self.slo_classes:
            if self.serving_batch_size is None:
                raise ValueError(
                    "slo_classes requires serving_batch_size (the request "
                    "rate is the observed job rate times the batch size)"
                )
            if self.speculation_quantiles:
                raise ValueError(
                    "slo_classes and speculation_quantiles are mutually "
                    "exclusive; use PolicyCandidate('clone', quantile=q) "
                    "entries in policy_candidates"
                )
            if self.coding_candidates:
                raise ValueError(
                    "slo_classes and coding_candidates are mutually "
                    "exclusive: the serving sweep scores replication "
                    "policies only"
                )
        elif (
            self.max_wait_candidates
            or self.shed_candidates
            or self.serving_batch_size is not None
        ):
            raise ValueError(
                "serving_batch_size / max_wait_candidates / shed_candidates "
                "only apply with slo_classes"
            )
        # measured job-arrival offsets (non-Poisson traffic): threaded into
        # the load-aware sweep so candidates are scored under the arrival
        # process the engine actually runs, not a Poisson stand-in
        self.arrival_offsets = (
            tuple(float(a) for a in np.asarray(arrival_offsets, float).ravel())
            if arrival_offsets is not None and np.asarray(arrival_offsets).size
            else None
        )
        self._times: deque[np.ndarray] = deque(maxlen=self.config.window_steps)
        self._censored: deque[np.ndarray] = deque(maxlen=self.config.window_steps)
        # wall-clock (tagged) telemetry: per-worker censored-MLE accumulators
        # keyed by caller-assigned worker id.  Cluster jobs observe a
        # VARIABLE number of replicas per completion (r changes with B, the
        # fleet shrinks on kills), so the fixed-shape window behind
        # worker_rates() never applies there; each id instead accumulates
        # (n_uncensored, total_time, n_observations) exactly like the
        # windowed estimator — see rates_for().
        self._tagged: deque[tuple[np.ndarray, np.ndarray, np.ndarray]] = (
            deque(maxlen=self.config.window_steps)
        )
        self._load: deque[float] = deque(maxlen=self.config.window_steps)
        self._sojourns: deque[np.ndarray] = deque(
            maxlen=self.config.window_steps
        )
        # (n_missed, n_total) per observation: windowed deadline-miss
        # telemetry, one entry per resolved request — sized in request
        # units (TunerConfig.miss_window), window_steps entries by default
        self._miss_window = (
            self.config.miss_window
            if self.config.miss_window is not None
            else self.config.window_steps
        )
        if self._miss_window < 1:
            raise ValueError(
                f"miss_window must be >= 1, got {self._miss_window}"
            )
        self._misses: deque[tuple[int, int]] = deque(
            maxlen=self._miss_window
        )
        # same telemetry split per SLO class (key = class name): the
        # per-class windows drive class-target breach detection — a fleet
        # meeting its GLOBAL miss target can still be starving one tenant
        self._class_misses: dict[str, deque[tuple[int, int]]] = {}
        self._step = 0
        self._last_replan = -(10**9)
        self._last_attempt = -(10**9)
        self.last_fit: Optional[FitResult] = None
        self.last_plan: Optional[Plan] = None
        self.last_gof = None
        self.last_replan_seconds = None
        self._gof_fallback: Optional[Planner] = None  # lazy EmpiricalPlanner

    def observe(
        self, step_times: np.ndarray, censored: np.ndarray | None = None
    ) -> None:
        """Record one step of per-worker service times.

        ``step_times`` are normalized to PER-UNIT-OF-DATA times (divide the
        measured time by the worker's batch size) so that fits are comparable
        across different B.  Infinite times (dead workers) are recorded as
        censored at the max finite time.
        """
        t = np.asarray(step_times, dtype=float).copy()
        c = (
            np.zeros(t.shape, dtype=bool)
            if censored is None
            else np.asarray(censored, dtype=bool).copy()
        )
        dead = ~np.isfinite(t)
        if dead.all():
            return  # nothing usable this step
        if dead.any():
            t[dead] = t[~dead].max()
            c |= dead
        self._times.append(t)
        self._censored.append(c)
        self._step += 1

    def observe_tagged(
        self,
        worker_ids: np.ndarray,
        times: np.ndarray,
        censored: np.ndarray | None = None,
    ) -> None:
        """Record wall-clock observations ATTRIBUTED to specific workers.

        The multi-process cluster runtime feeds per-job telemetry here: a
        completed batch contributes one (possibly censored) service time per
        replica that ran it, tagged with the worker id that produced it.
        Unlike :meth:`observe`, rows may cover any SUBSET of the fleet and
        any number of replicas — exactly what wall-clock dispatch produces
        (r changes with B, workers die, clones run on other sets).

        The observations join the same sliding window :meth:`fit` and the
        re-plan path consume (so fits, KS gates, and empirical re-plans see
        wall-clock telemetry unchanged), AND accumulate per-worker for
        :meth:`rates_for` — the kill-/cancellation-censored per-worker rate
        estimates recovery planning feeds to
        :meth:`repro.distributed.fault.FaultManager.plan_recovery`.
        """
        ids = np.asarray(worker_ids, dtype=int).ravel()
        t = np.asarray(times, dtype=float).ravel()
        if ids.shape != t.shape:
            raise ValueError(
                f"worker_ids shape {ids.shape} != times shape {t.shape}"
            )
        c = (
            np.zeros(t.shape, dtype=bool)
            if censored is None
            else np.asarray(censored, dtype=bool).ravel()
        )
        if c.shape != t.shape:
            raise ValueError(
                f"censored shape {c.shape} != times shape {t.shape}"
            )
        keep = np.isfinite(t) & (t > 0)
        if not keep.any():
            return
        self._tagged.append((ids[keep], t[keep], c[keep]))
        self.observe(t[keep], censored=c[keep])

    def rates_for(self, worker_ids) -> Optional[np.ndarray]:
        """Per-worker relative rates for ``worker_ids`` from tagged telemetry.

        Same censored-exponential MLE as :meth:`worker_rates`
        (``rate ~ n_uncensored / sum(times)``, half a pseudo-observation
        for all-censored workers, normalized to mean 1) but computed from
        the :meth:`observe_tagged` accumulators, so it tolerates the
        variable-shape observations wall-clock dispatch produces.  Returns
        None until every requested worker has at least one observation —
        recovery planning falls back to a homogeneous spec rather than
        guessing rates for an unmeasured worker.
        """
        ids = [int(w) for w in worker_ids]
        if not ids or not self._tagged:
            return None
        n_unc: dict[int, float] = {w: 0.0 for w in ids}
        total: dict[int, float] = {w: 0.0 for w in ids}
        wanted = set(ids)
        for row_ids, row_t, row_c in self._tagged:
            for w, t, c in zip(row_ids, row_t, row_c):
                w = int(w)
                if w in wanted:
                    total[w] += float(t)
                    n_unc[w] += 0.0 if c else 1.0
        if any(total[w] <= 0 for w in ids):
            return None
        rates = np.array([max(n_unc[w], 0.5) / total[w] for w in ids])
        return rates / rates.mean()

    def observe_load(self, arrival_rate: float) -> None:
        """Record one observation of the batch-job arrival rate.

        The serving engine feeds its measured formation rate here; the
        windowed mean becomes the ``arrival_rate`` of the re-plan Objective
        when the planner can consume load, closing the loop on real traffic
        instead of an operator-guessed constant.
        """
        if np.isfinite(arrival_rate) and arrival_rate > 0:
            self._load.append(float(arrival_rate))

    @property
    def observed_arrival_rate(self) -> Optional[float]:
        """Windowed mean of the observed batch-job arrival rate."""
        if not self._load:
            return None
        return float(np.mean(self._load))

    def observe_sojourn(self, sojourns: np.ndarray) -> None:
        """Record per-request sojourn times (queue wait + service).

        Used as the OBSERVED baseline in load-aware hysteresis: a predicted
        win is measured against the latency requests actually experienced at
        the current B, not against the model's own prediction of it.
        """
        s = np.asarray(sojourns, dtype=float).ravel()
        s = s[np.isfinite(s)]
        if s.size:
            self._sojourns.append(s)

    def observe_deadline_misses(
        self, n_missed: int, n_total: int, slo: str = ""
    ) -> None:
        """Record SLO outcomes: of ``n_total`` deadline-carrying requests
        that resolved (served or dropped), ``n_missed`` missed.

        The windowed rate (:attr:`observed_miss_rate`) is the SLO re-plan
        trigger: past ``TunerConfig.miss_rate_target`` the next re-plan
        skips the hysteresis threshold — a fleet in breach moves for any
        predicted win, not just a large one.  ``slo`` attributes the
        observation to a tenant class; per-class windows
        (:meth:`class_miss_rates`) then drive class-target breach
        detection for multi-tenant objectives.
        """
        if n_total < 0 or not 0 <= n_missed <= max(n_total, 0):
            raise ValueError(
                f"invalid miss telemetry ({n_missed}/{n_total})"
            )
        if n_total > 0:
            self._misses.append((int(n_missed), int(n_total)))
            if slo:
                lane = self._class_misses.get(slo)
                if lane is None:
                    lane = deque(maxlen=self._miss_window)
                    self._class_misses[slo] = lane
                lane.append((int(n_missed), int(n_total)))

    @property
    def observed_miss_rate(self) -> Optional[float]:
        """Windowed deadline-miss fraction (None without miss telemetry)."""
        if not self._misses:
            return None
        missed = sum(m for m, _ in self._misses)
        total = sum(t for _, t in self._misses)
        return missed / total

    def class_miss_rates(self) -> dict[str, float]:
        """Windowed deadline-miss fraction per SLO class (observed classes
        only — a class with no resolved deadline-carrying requests in the
        window has no entry)."""
        out: dict[str, float] = {}
        for name, lane in self._class_misses.items():
            total = sum(t for _, t in lane)
            if total > 0:
                out[name] = sum(m for m, _ in lane) / total
        return out

    def _class_target_breached(self) -> bool:
        """Whether any SLO class with a miss target is over it (windowed)."""
        if not self.slo_classes:
            return False
        rates = self.class_miss_rates()
        return any(
            c.miss_target is not None
            and rates.get(c.name) is not None
            and rates[c.name] > c.miss_target
            for c in self.slo_classes
        )

    def observed_sojourn(self, metric: Metric) -> Optional[float]:
        """The objective metric evaluated on the observed sojourn window."""
        if not self._sojourns:
            return None
        s = np.concatenate(list(self._sojourns))
        if s.size < 2:
            return None
        if metric == "mean":
            return float(s.mean())
        if metric == "var":
            return float(s.var(ddof=1))
        if metric == "p99":
            return float(np.quantile(s, 0.99))
        if metric == "p999":
            return float(np.quantile(s, 0.999))
        raise ValueError(f"unknown metric {metric!r}")

    @property
    def n_samples(self) -> int:
        return int(sum(t.size for t in self._times))

    def window_observations(self) -> tuple[np.ndarray, np.ndarray]:
        """The flattened observation window: (times, censored_mask)."""
        x = np.concatenate([t.ravel() for t in self._times])
        c = np.concatenate([m.ravel() for m in self._censored])
        return x, c

    def fit(self) -> Optional[FitResult]:
        if self.n_samples < self.config.min_samples:
            return None
        x, c = self.window_observations()
        if (~c).sum() == 0:
            return None
        self.last_fit = fit_best(x, c)
        return self.last_fit

    def empirical_dist(self) -> Empirical:
        """The observation window as a censoring-aware Empirical (KM ECDF).

        The distribution the empirical re-plan path hands to
        :class:`~repro.core.planner.EmpiricalPlanner` — the fleet as
        measured, no parametric family assumed.
        """
        x, c = self.window_observations()
        return Empirical.from_censored(x, c)

    def _empirical_fallback_planner(self) -> Planner:
        """The EmpiricalPlanner used when the GoF gate rejects the fit
        (built once, from the config's sim budget)."""
        if self._gof_fallback is None:
            self._gof_fallback = make_planner(
                mode="empirical",
                n_trials=self.config.sim_trials,
                seed=self.config.sim_seed,
                backend=self.config.sim_backend,
                n_resamples=self.config.bootstrap_resamples,
            )
        return self._gof_fallback

    def worker_rates(self) -> Optional[np.ndarray]:
        """Per-worker relative service rates estimated from the window.

        Censored-exponential MLE per worker: ``rate_j ~ n_uncensored_j /
        sum(times_j)`` — censored observations still contribute their
        lower-bound time to the denominator, so a persistently-censored
        slow worker is estimated SLOW instead of being dropped (discarding
        censored draws would keep only a straggler's lucky fast ones and
        bias its rate high).  A worker with zero uncensored observations
        gets a half pseudo-observation to stay finite-and-slow.  Rates are
        normalized to mean 1 (the fitted mu carries the absolute scale).

        Returns None on an empty window or while the window holds mixed
        worker counts (mid-elastic-resize) — callers fall back to the
        homogeneous plan until a clean window accumulates.
        """
        if not self._times:
            return None
        if len({t.shape for t in self._times}) != 1:
            return None
        t = np.stack(list(self._times))  # (steps, N)
        c = np.stack(list(self._censored))
        n_unc = (~c).sum(axis=0).astype(float)
        total = t.sum(axis=0)
        if np.any(total <= 0):
            return None
        rates = np.maximum(n_unc, 0.5) / total
        return rates / rates.mean()

    def cluster_spec(self, fit: FitResult) -> ClusterSpec:
        """The fleet as currently observed: fitted dist + (optional) rates.

        Rates are only attached when the planner can consume them (a
        rate-incapable planner would otherwise reject the spec outright).
        """
        rates = None
        if self.planner.consumes_rates:
            rates = self.worker_rates()
            if rates is not None and len(rates) != self.plan.n_data:
                rates = None  # observed fleet != plan size: homogeneous fallback
        return ClusterSpec.from_fit(
            fit, self.plan.n_data, rates=rates,
            batch_divisor=self.batch_divisor,
        )

    def objective(self, planner: Optional[Planner] = None) -> Objective:
        """The re-plan Objective: the config's, upgraded with observed load.

        When the planner can score load-aware objectives and the engine has
        fed arrival-rate telemetry (:meth:`observe_load`), the objective
        carries the OBSERVED offered load — the planner then optimizes
        sojourn under real traffic rather than batch completion.
        ``planner`` is the planner this attempt will actually use (the GoF
        gate may have swapped in the empirical fallback); defaults to the
        primary.
        """
        planner = planner if planner is not None else self.planner
        objective = self.config.objective()
        rate = self.observed_arrival_rate
        if planner.consumes_load and rate is not None:
            objective = dataclasses.replace(
                objective,
                arrival_rate=rate,
                utilization=None,
                job_load=self.job_load,
                speculation_quantiles=self.speculation_quantiles,
                policies=self.policy_candidates,
                arrivals=self.arrival_offsets,
            )
            # multi-tenant serving: a class-capable planner re-plans with
            # the full per-request objective — the sweep then co-optimizes
            # (B, policy, max_wait, shed) and reports per-class miss rates
            if self.slo_classes and getattr(planner, "consumes_classes", False):
                objective = dataclasses.replace(
                    objective,
                    slo_classes=self.slo_classes,
                    batch_size=self.serving_batch_size,
                    max_waits=self.max_wait_candidates,
                    sheds=self.shed_candidates,
                )
        # the coded race applies to BOTH modes (batch completion and
        # sojourn); gate on consumes_load as the "simulated planner"
        # capability — the closed-form planner cannot score coded cells
        if self.coding_candidates and planner.consumes_load:
            objective = dataclasses.replace(
                objective, coding=self.coding_candidates
            )
        return objective

    def _cooldown_waived(self) -> bool:
        """Whether re-plan pacing is waived by the measured-time budget.

        True when ``TunerConfig.replan_time_budget`` is set and the last
        measured ``planner.plan()`` call came in at or under it: the
        cooldown exists to amortize expensive sweeps, and once the sweep
        is measured-cheap (accelerator-resident backend) pacing only
        delays reactions to drift.  Hysteresis still gates the MOVES —
        only the attempt rate is freed.  The first attempt after
        construction is never waived (no measurement yet), so a slow
        numpy sweep can never sneak through on an optimistic default.
        """
        budget = self.config.replan_time_budget
        return (
            budget is not None
            and self.last_replan_seconds is not None
            and self.last_replan_seconds <= budget
        )

    def maybe_replan(self) -> Optional[RescalePlan]:
        """Fit, delegate the B decision to the Planner, and emit a rescale
        plan if the predicted win clears the Objective's hysteresis."""
        if not self._cooldown_waived():
            if self._step - self._last_replan < self.config.cooldown_steps:
                return None
            # the cooldown also paces plan EVALUATIONS that did not move B:
            # a load-aware sweep is ~10^2 slower than the closed forms, and
            # re-scoring the whole spectrum after every observation would
            # make telemetry ingestion O(sweep).  Attempts that bailed for
            # lack of data (no fit yet) do not count.
            if self._step - self._last_attempt < self.config.cooldown_steps:
                return None
        if self.n_samples < self.config.min_samples:
            return None
        x, c = self.window_observations()
        if (~c).sum() == 0:
            return None
        planner = self.planner
        use_empirical = planner.consumes_empirical
        self.last_gof = None
        fit: Optional[FitResult] = None
        if not use_empirical:
            fit = self.fit()
            if fit is None:
                return None
            # goodness-of-fit gate: a parametric fit the window rejects must
            # not drive the B decision — reroute THIS attempt through the
            # empirical path (the primary planner stays installed; a later
            # well-fitting window flows back to it automatically)
            if self.config.gof_alpha is not None:
                self.last_gof = goodness_of_fit(
                    x, fit.dist, c, alpha=self.config.gof_alpha
                )
                if self.last_gof.rejected:
                    planner = self._empirical_fallback_planner()
                    use_empirical = True
        objective = self.objective(planner)
        if use_empirical:
            # the spec's dist is the window itself (KM ECDF); rates are
            # dropped — EmpiricalPlanner quantifies distributional
            # uncertainty, not per-worker skew.  On the empirical-PRIMARY
            # path no parametric MLE runs at all (the fit would be thrown
            # away); the RescalePlan's fit record is computed lazily below,
            # only when a move is actually emitted.
            spec = ClusterSpec(
                n_workers=self.plan.n_data,
                dist=self.empirical_dist(),
                batch_divisor=self.batch_divisor,
            )
        else:
            spec = self.cluster_spec(fit)
        t0 = time.perf_counter()
        plan = planner.plan(spec, objective)
        self.last_replan_seconds = time.perf_counter() - t0
        self.last_plan = plan
        self._last_attempt = self._step
        if plan.n_batches == self.plan.n_batches:
            return None
        # current B absent from the sweep means it is no longer feasible
        # (e.g. a new batch_divisor constraint): the move is FORCED, so it
        # bypasses hysteresis — including any observed-sojourn baseline —
        # and reports an infinite predicted win.
        cur = plan.predicted_at(self.plan.n_batches)
        if cur is None:
            improvement = math.inf
        else:
            baselines = [cur]
            if objective.load_aware:
                # sojourn telemetry is the ground truth for what the current
                # B costs.  The predicted win must clear hysteresis against
                # BOTH the model's CRN-consistent estimate of the current B
                # (which kills ping-pong between near-tied candidates) and
                # the latency requests actually experienced (which kills
                # moves justified only by model optimism).  The window is
                # cleared on apply() — it must describe the CURRENT
                # configuration, not the drain transient of the last move —
                # so require a refilled window before trusting its quantiles.
                observed = self.observed_sojourn(objective.metric)
                n_observed = sum(s.size for s in self._sojourns)
                if (
                    observed is not None
                    and n_observed >= self.config.min_samples
                ):
                    baselines.append(observed)
            cur = min(baselines)
            improvement = 1.0 - plan.score / max(cur, 1e-30)
        # SLO breach waives hysteresis: while the observed deadline-miss
        # rate exceeds the target, ANY predicted win justifies moving (the
        # cooldown still paces the attempts, so near-ties cannot ping-pong
        # faster than one move per cooldown window)
        threshold = self.config.improvement_threshold
        miss_rate = self.observed_miss_rate
        if (
            self.config.miss_rate_target is not None
            and miss_rate is not None
            and miss_rate > self.config.miss_rate_target
        ):
            threshold = 0.0
        # a PER-CLASS target in breach waives hysteresis too: the global
        # rate can look healthy while a premium tenant is starving
        if self._class_target_breached():
            threshold = 0.0
        if improvement < threshold:
            return None
        self._last_replan = self._step
        if fit is None:  # empirical-primary path: fit only for the record
            fit = self.fit()
        return RescalePlan(
            old_batches=self.plan.n_batches,
            new_batches=plan.n_batches,
            predicted_old=cur if cur is not None else math.inf,
            predicted_new=plan.score,
            fit=fit,
            step=self._step,
            plan=plan,
        )

    def apply(self, plan: RescalePlan) -> ReplicationPlan:
        """Commit a re-plan (the caller re-factors the mesh + pipeline)."""
        self.plan = ReplicationPlan(
            n_data=self.plan.n_data, n_batches=plan.new_batches
        )
        # sojourn + miss telemetry describe the configuration they were
        # measured under; keeping the old B's (and the move's drain-
        # transient) observations would let every move justify the next one
        self._sojourns.clear()
        self._misses.clear()
        self._class_misses.clear()
        return self.plan
