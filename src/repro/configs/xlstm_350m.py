"""xLSTM-350M: 24 blocks, 21 mLSTM + 3 sLSTM (7:1 ratio), no separate FFN
(d_ff=0; the blocks carry their own up/down projections).

[arXiv:2405.04517] 24L d_model=1024 4H d_ff=0 vocab=50304.

Sub-quadratic: recurrent state decode -> runs the long_500k cell.
Simplifications recorded in DESIGN.md: sLSTM uses diagonal (per-head)
sigmoid-gated linear recurrence via associative scan (no block-diagonal
memory mixing); mLSTM uses the chunkwise stabilized exponential-gating form.
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    norm="rmsnorm",
    use_rope=False,
    ssm=SSMConfig(
        state_dim=256,  # mLSTM qk dim per head
        head_dim=512,  # v dim per head (2x expansion / 4 heads)
        expansion=2,
        conv_kernel=4,
        chunk=128,
        slstm_layers=(7, 15, 23),  # 7:1 mLSTM:sLSTM ratio over 24 blocks
    ),
    subquadratic=True,
)
