"""Event-driven serving master: admission queue, batch formation, replica
dispatch with first-replica-wins cancellation, speculative re-dispatch, and
deadline (EDF) scheduling.

This is the discrete-event core the engine drives the model from.  The fleet
is factored (per the active :class:`~repro.core.planner.Plan`) into
``n_groups`` replica-sets — one per batch slot, each holding ``r`` server
groups.  The master's event loop:

* **Admission** — requests enter the queue at their arrival time under one of
  four disciplines (``QueuePolicy.discipline``): ``'fifo'`` (arrival order),
  ``'priority'`` (larger ``Request.priority`` first, ties FIFO), ``'edf'``
  (earliest ``Request.deadline`` first, ties FIFO — the deadline/SLO
  discipline), or ``'wfq'`` (weighted fair queueing across ``Request.slo``
  tenant classes: each class keeps FIFO order internally and classes share
  formation slots in proportion to ``QueuePolicy.class_weights``, stride-
  scheduled so no backlogged class ever starves).  With
  ``QueuePolicy.drop_expired`` set, a request whose deadline has already
  passed is DROPPED instead of queued (at admission) or instead of
  dispatched (at batch formation); with ``QueuePolicy.queue_cap`` set, an
  arriving request finding the admission queue at capacity is shed on the
  spot (admission-control load shedding — weight-aware under ``'wfq'``,
  where a heavier-class arrival instead evicts the newest request of the
  cheapest backlogged class).  Dropped requests land in
  :attr:`EventDrivenMaster.dropped_requests` and never occupy a replica-set.
* **Batch formation** — a batch forms as soon as ``max_batch_size`` requests
  wait, or when the OLDEST queued request has waited ``max_wait`` (whichever
  comes first; the master keeps exactly one formation timer armed at
  ``oldest_arrival + max_wait`` and re-arms it after every formation, so the
  bound holds under every discipline, including the ones whose pop order is
  not arrival order); leftovers are flushed once the arrival stream ends, so
  no request is ever dropped by formation (the lock-step engine's remainder
  bug — see :func:`partition_requests`).  A batch inherits the EARLIEST
  deadline and the LARGEST priority of its requests.
* **Replica dispatch** — a formed batch goes to the lowest-numbered idle
  replica-set (under ``'priority'``/``'edf'`` an urgent batch overtakes
  earlier-formed pending ones); its ``r`` replicas all start, the FASTEST
  one's response completes the batch and the rest are cancelled (the paper's
  ``min``-over-replicas rule), so the whole set frees at the winner's time.
* **Straggler mitigation** — a :class:`StragglerPolicy` decides what to do
  about late responses, all variants sharing the same event clock,
  first-completion-wins cancellation, and censored-telemetry accounting:

  - :class:`ClonePolicy` (speculative re-dispatch, the PR-4 behavior and
    the alias :class:`SpeculationPolicy`): a batch whose first response is
    LATE (no response by the policy's late-quantile threshold after
    dispatch) is cloned onto an idle replica-set, Aktaş et al.
    clone-attack style — the clone's ``r`` replicas race the originals,
    whichever responds first completes the batch, and every other replica
    is cancelled.  Clones only ever take sets that are idle at the trigger
    instant (a queued batch is never displaced), and each job spends at
    most ``max_clones`` from its clone budget.
  - :class:`RelaunchPolicy`: a late batch's in-flight replica set is
    CANCELLED and the batch re-dispatches fresh on the same set (no extra
    capacity consumed; Behrouzi-Far/Soljanin 2020's relaunch arm, which
    pays off only when service has memory — under Exp it is a
    distributional no-op).  Discarded attempts are kept, censored at the
    relaunch instant, for telemetry.
  - :class:`HedgedDispatchPolicy`: a deterministic-stride fraction of jobs
    dispatches to ``k`` replica-sets UP FRONT (primary + hedges racing
    from t=0), spending idle capacity at dispatch time instead of waiting
    for a late signal.
  - :class:`NoOpPolicy`: never intervene (explicit baseline).
* **Sojourn accounting** — every request records arrival, dispatch, and
  completion; sojourn = queue wait + service, the metric the load-aware
  planner objectives act on.  Requests carrying a finite ``deadline`` also
  report :attr:`Request.missed_deadline`.

Re-planning: ``on_job_complete`` may return a reconfiguration (new
``n_groups``, sampler, and/or ``policy`` — a replacement
:class:`QueuePolicy` with the same discipline/weights, so a swept
``max_wait`` or shed cap lands on the live master).  The master then DRAINS — formed batches keep
queueing, in-flight batches finish, no new clones launch — and swaps the
replica-set fabric only at the quiesce point, mirroring how re-factoring a
real mesh flushes compiled executables before traffic resumes.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from collections import deque
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = [
    "QueuePolicy",
    "StragglerPolicy",
    "NoOpPolicy",
    "ClonePolicy",
    "SpeculationPolicy",
    "RelaunchPolicy",
    "HedgedDispatchPolicy",
    "Request",
    "BatchJob",
    "AdmissionQueue",
    "EventDrivenMaster",
    "job_observations",
    "late_threshold",
    "partition_requests",
]


def partition_requests(n_requests: int, n_batches: int) -> list[tuple[int, int]]:
    """Contiguous [lo, hi) request slices for one synchronized round.

    The legacy ``serve_round`` sliced ``per_batch = max(n // B, 1)`` requests
    per batch and DROPPED the remainder (``n=10, B=4`` served only 8).  Here
    the LAST batch absorbs the remainder, so every request is assigned; with
    ``B | n`` the slices are identical to the legacy ones.  Empty trailing
    slices (``n < B``) are preserved so callers can keep slice index == batch
    index.

    >>> partition_requests(10, 4)
    [(0, 2), (2, 4), (4, 6), (6, 10)]
    """
    if n_batches < 1:
        raise ValueError(f"n_batches must be >= 1, got {n_batches}")
    if n_requests < 0:
        raise ValueError(f"n_requests must be >= 0, got {n_requests}")
    per_batch = max(n_requests // n_batches, 1)
    slices = []
    for bi in range(n_batches):
        lo = min(bi * per_batch, n_requests)
        hi = min((bi + 1) * per_batch, n_requests)
        if bi == n_batches - 1:
            hi = n_requests  # the remainder rides with the last batch
        slices.append((lo, hi))
    return slices


@dataclasses.dataclass(frozen=True)
class QueuePolicy:
    """Admission + batch-formation knobs of the event-driven master.

    * ``max_batch_size`` — form a batch as soon as this many requests wait.
    * ``max_wait``       — ... or when the OLDEST queued request has waited
      this long.  The master keeps one formation timer armed at
      ``oldest_arrival + max_wait`` (re-armed after every formation), so
      the bound is oldest-waiting under EVERY discipline — including
      ``'edf'``/``'priority'``/``'wfq'``, whose pop order is not arrival
      order.
    * ``discipline``     — ``'fifo'`` | ``'priority'`` (larger
      :attr:`Request.priority` first) | ``'edf'`` (earliest
      :attr:`Request.deadline` first; requests without a deadline sort last)
      | ``'wfq'`` (weighted fair queueing across :attr:`Request.slo` tenant
      classes, see ``class_weights``).
    * ``class_weights``  — ``((class_name, weight), ...)`` fair-share
      weights for ``'wfq'`` (hashable so planner sweeps can carry it).
      Classes not listed get weight 1.0; under sustained backlog each
      class's share of formation slots converges to its weight fraction,
      and no backlogged class ever starves (stride scheduling).
    * ``drop_expired``   — drop a request whose deadline has already passed
      instead of admitting/dispatching it (the SLO "don't serve dead work"
      knob; default off, so late requests are still served and merely
      counted as deadline misses).
    * ``queue_cap``      — admission-control load shedding: an arriving
      request finding this many requests already queued is dropped instead
      of admitted (bounds queue wait under overload; ``None`` = unbounded).
      Under ``'wfq'`` the shedding is weight-aware: an arrival of a
      heavier class evicts the NEWEST queued request of the cheapest
      backlogged class instead of being shed itself (see
      :meth:`AdmissionQueue.evict_for`), so overload pressure lands on the
      low-weight tenants first.  A cap also THROTTLES size-triggered
      formation to ``n_groups`` pending batches (see
      :meth:`EventDrivenMaster._maybe_form`): overload backlog then
      accumulates in the admission queue where the cap acts, instead of
      draining into the unbounded formed-batch buffer.

    >>> QueuePolicy(max_batch_size=8, discipline="edf", drop_expired=True)
    QueuePolicy(max_batch_size=8, max_wait=inf, discipline='edf', class_weights=None, drop_expired=True, queue_cap=None)
    """

    max_batch_size: int = 4  # form a batch as soon as this many wait
    max_wait: float = math.inf  # ... or the oldest has waited this long
    discipline: str = "fifo"  # 'fifo' | 'priority' | 'edf' | 'wfq'
    class_weights: Optional[tuple] = None  # ((slo, weight), ...) for 'wfq'
    drop_expired: bool = False  # drop requests already past their deadline
    queue_cap: Optional[int] = None  # shed arrivals beyond this queue length

    def __post_init__(self):
        if self.max_batch_size < 1:
            raise ValueError(
                f"max_batch_size must be >= 1, got {self.max_batch_size}"
            )
        if not self.max_wait > 0:
            raise ValueError(f"max_wait must be positive, got {self.max_wait}")
        if self.discipline not in ("fifo", "priority", "edf", "wfq"):
            raise ValueError(
                f"unknown discipline {self.discipline!r} "
                "(use 'fifo'|'priority'|'edf'|'wfq')"
            )
        if self.class_weights is not None:
            if self.discipline != "wfq":
                raise ValueError(
                    "class_weights only applies to the 'wfq' discipline"
                )
            cw = tuple((str(n), float(w)) for n, w in self.class_weights)
            if any(w <= 0 or not math.isfinite(w) for _, w in cw):
                raise ValueError(
                    f"class weights must be positive finite, got {cw}"
                )
            if len({n for n, _ in cw}) != len(cw):
                raise ValueError(f"duplicate class names in {cw}")
            object.__setattr__(self, "class_weights", cw)
        if self.queue_cap is not None and self.queue_cap < 1:
            raise ValueError(
                f"queue_cap must be >= 1, got {self.queue_cap}"
            )


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    """Base class of the master's straggler-mitigation policies.

    One policy instance is wired into :class:`EventDrivenMaster` (the
    ``speculation=`` / ``straggler_policy=`` knob); concrete subclasses are
    :class:`ClonePolicy` (and its legacy alias :class:`SpeculationPolicy`),
    :class:`RelaunchPolicy`, :class:`HedgedDispatchPolicy`, and
    :class:`NoOpPolicy`.  All share the master's event clock,
    first-completion-wins cancellation, and censored-telemetry accounting.
    """


@dataclasses.dataclass(frozen=True)
class NoOpPolicy(StragglerPolicy):
    """Never intervene — the explicit do-nothing baseline (equivalent to
    running the master with no policy at all, but nameable in configs and
    planner sweeps)."""


def _validate_trigger_fields(pol) -> None:
    """Shared validation of the late-trigger knobs (clone + relaunch)."""
    if not 0.0 < pol.late_quantile < 1.0:
        raise ValueError(
            f"late_quantile must be in (0, 1), got {pol.late_quantile}"
        )
    if pol.min_observations < 1:
        raise ValueError(
            f"min_observations must be >= 1, got {pol.min_observations}"
        )


@dataclasses.dataclass(frozen=True)
class ClonePolicy(StragglerPolicy):
    """When (and how much) to clone a late batch (speculative re-dispatch).

    A batch dispatched at time ``t`` whose first response has not arrived by
    ``t + threshold`` is LATE; the master then launches a clone of the whole
    batch on an idle replica-set (if one exists), first-replica-wins across
    originals and clones.  The threshold is, in order of preference:

    * ``threshold(job)`` — caller-supplied model, e.g. the ``late_quantile``
      of the fitted min-over-replicas service distribution (what the serving
      engine wires in); or
    * the empirical ``late_quantile`` of the master's own window of observed
      batch service times, once ``min_observations`` jobs have completed
      (self-calibrating fallback when no fitted model is available).

    ``max_clones`` is the per-job clone budget: after a clone launches, the
    trigger re-arms one threshold later until the budget is spent.  Clones
    are launched ONLY onto sets idle at the trigger instant — speculation
    spends spare capacity, never displaces queued work.

    >>> ClonePolicy(late_quantile=0.9, max_clones=1)
    ClonePolicy(late_quantile=0.9, max_clones=1, min_observations=8, threshold=None)
    """

    late_quantile: float = 0.9  # trigger when the response is this late
    max_clones: int = 1  # per-job clone budget
    min_observations: int = 8  # window size gating the empirical fallback
    threshold: Optional[Callable[["BatchJob"], float]] = None

    def __post_init__(self):
        _validate_trigger_fields(self)
        if self.max_clones < 0:
            raise ValueError(
                f"max_clones must be >= 0, got {self.max_clones}"
            )


@dataclasses.dataclass(frozen=True)
class SpeculationPolicy(ClonePolicy):
    """Pre-portfolio name of :class:`ClonePolicy`, kept as an alias so
    existing configs and pickles keep working (see docs/migration.md)."""


@dataclasses.dataclass(frozen=True)
class RelaunchPolicy(StragglerPolicy):
    """Cancel a late batch's in-flight attempt and re-dispatch it FRESH.

    Same late-trigger machinery as :class:`ClonePolicy` (caller-supplied
    ``threshold`` model, else the empirical ``late_quantile`` of observed
    batch services), but instead of spending an extra replica-set the
    master CANCELS the running replicas and draws a brand-new attempt on
    the same set.  No extra capacity is consumed, so relaunch helps exactly
    when service has memory (the elapsed wait predicts a long remainder) —
    under exponential service it is a distributional no-op, the regime
    boundary Behrouzi-Far/Soljanin 2020 pins.  ``max_relaunches`` bounds
    attempts per job; discarded attempts are kept on the job, censored at
    the relaunch instant, for telemetry.

    >>> RelaunchPolicy(late_quantile=0.9)
    RelaunchPolicy(late_quantile=0.9, max_relaunches=1, min_observations=8, threshold=None)
    """

    late_quantile: float = 0.9  # trigger when the response is this late
    max_relaunches: int = 1  # per-job relaunch budget
    min_observations: int = 8  # window size gating the empirical fallback
    threshold: Optional[Callable[["BatchJob"], float]] = None

    def __post_init__(self):
        _validate_trigger_fields(self)
        if self.max_relaunches < 0:
            raise ValueError(
                f"max_relaunches must be >= 0, got {self.max_relaunches}"
            )


@dataclasses.dataclass(frozen=True)
class HedgedDispatchPolicy(StragglerPolicy):
    """Dispatch a job to ``k`` replica-sets UP FRONT (hedged requests).

    A deterministic-stride ``hedge_fraction`` of dispatched jobs grabs up
    to ``k - 1`` ADDITIONAL idle replica-sets at dispatch time (job ``n``
    is hedged iff ``floor((n+1)f) > floor(nf)`` — reproducible, no RNG);
    all sets race from t=0, first response wins, the rest are cancelled.
    Hedges only take sets idle at the dispatch instant, so queued work is
    never displaced — hedging converts spare capacity into tail latency up
    front instead of waiting for a late signal, which wins under
    heavy-tailed service and loses under light load-sensitive regimes.

    >>> HedgedDispatchPolicy(k=2, hedge_fraction=0.5)
    HedgedDispatchPolicy(k=2, hedge_fraction=0.5)
    """

    k: int = 2  # replica-sets per hedged job (primary + k-1 hedges)
    hedge_fraction: float = 1.0  # fraction of jobs hedged (stride-selected)

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if not 0.0 <= self.hedge_fraction <= 1.0:
            raise ValueError(
                f"hedge_fraction must be in [0, 1], got {self.hedge_fraction}"
            )


@dataclasses.dataclass
class Request:
    """One user request moving through the queueing subsystem.

    ``priority`` matters under the ``'priority'`` discipline (larger = more
    urgent); ``deadline`` (ABSOLUTE sim-time, default +inf = no SLO) drives
    the ``'edf'`` discipline, drop-on-expiry, and miss accounting; ``slo`` is
    a free-form class label for per-class reporting.  ``dropped`` marks a
    request shed by drop-on-expiry — it never ran, so its ``completion``
    stays NaN.

    >>> r = Request(request_id=0, arrival=1.0, deadline=3.0)
    >>> r.dispatched, r.completion = 1.5, 2.5
    >>> r.sojourn, r.missed_deadline
    (1.5, False)
    """

    request_id: int
    arrival: float
    priority: float = 0.0  # larger = more urgent ('priority' discipline only)
    deadline: float = math.inf  # absolute SLO deadline ('edf' + miss stats)
    slo: str = ""  # optional SLO class label (reporting only)
    batch_id: int = -1
    dispatched: float = math.nan
    completion: float = math.nan
    dropped: bool = False  # shed by drop-on-expiry, never served

    @property
    def queue_wait(self) -> float:
        return self.dispatched - self.arrival

    @property
    def sojourn(self) -> float:
        """Queue wait + service: the latency the user actually feels."""
        return self.completion - self.arrival

    @property
    def missed_deadline(self) -> bool:
        """True when the request has a deadline and did not make it (a
        dropped request counts as a miss; one still in flight does not)."""
        if not math.isfinite(self.deadline):
            return False
        return self.dropped or (
            math.isfinite(self.completion) and self.completion > self.deadline
        )


@dataclasses.dataclass
class BatchJob:
    """A formed batch of requests and its dispatch/telemetry record.

    One job occupies one replica-set (``group``) from ``dispatched`` until
    ``completed``; speculative clones AND up-front hedges occupy additional
    sets, recorded in the parallel lists ``clone_groups`` /
    ``clone_dispatched`` / ``clone_service_times``.  ``winner`` is the
    fastest ORIGINAL replica; ``winner_clone`` is -1 when an original won
    and otherwise the index of the winning clone/hedge (whose fastest
    replica supplied the result).  Under :class:`RelaunchPolicy`, cancelled
    attempts move to ``discarded_service_times`` (their relaunch instants
    in ``relaunched_at``) and ``service_times`` always holds the CURRENT
    attempt's draws.
    """

    batch_id: int
    requests: tuple[Request, ...]
    formed_at: float
    group: int = -1  # replica-set the batch ran on
    dispatched: float = math.nan
    completed: float = math.nan
    service_times: Optional[np.ndarray] = None  # per-replica draws
    winner: int = -1  # index of the fastest original replica
    # speculative re-dispatch record (parallel lists, one entry per clone)
    clone_groups: list[int] = dataclasses.field(default_factory=list)
    clone_dispatched: list[float] = dataclasses.field(default_factory=list)
    clone_service_times: list[np.ndarray] = dataclasses.field(
        default_factory=list
    )
    winner_clone: int = -1  # -1: an original replica won; else clone index
    # relaunch record (parallel lists, one entry per cancelled attempt)
    relaunched_at: list[float] = dataclasses.field(default_factory=list)
    discarded_service_times: list[np.ndarray] = dataclasses.field(
        default_factory=list
    )
    departed: bool = False  # internal: guards stale depart events

    @property
    def size(self) -> int:
        return len(self.requests)

    @property
    def priority(self) -> float:
        """A batch is as urgent as its most urgent request."""
        return max((r.priority for r in self.requests), default=0.0)

    @property
    def deadline(self) -> float:
        """A batch inherits the EARLIEST deadline of its requests (EDF)."""
        return min((r.deadline for r in self.requests), default=math.inf)

    @property
    def service(self) -> float:
        """Dispatch-to-completion time (clone wins shorten it)."""
        return self.completed - self.dispatched

    @property
    def n_clones(self) -> int:
        """How many speculative clones / hedges this job launched."""
        return len(self.clone_groups)

    @property
    def n_relaunches(self) -> int:
        """How many times this job's attempt was cancelled and re-drawn."""
        return len(self.relaunched_at)

    @property
    def attempt_dispatched(self) -> float:
        """Dispatch time of the CURRENT attempt on the original set (equals
        ``dispatched`` unless the job relaunched)."""
        return self.relaunched_at[-1] if self.relaunched_at else self.dispatched

    @property
    def attempt_service(self) -> float:
        """Current-attempt dispatch-to-completion time — the censoring bound
        for the live ``service_times`` draws (equals ``service`` unless the
        job relaunched)."""
        return self.completed - self.attempt_dispatched

    @property
    def groups(self) -> list[int]:
        """Every replica-set the job occupies (original + clones)."""
        return [self.group, *self.clone_groups]

    def used_mask(self) -> np.ndarray:
        """Per-ORIGINAL-replica mask: True for the replica whose result was
        used (all False when a speculative clone won the race)."""
        used = np.zeros(len(self.service_times), dtype=bool)
        if self.winner_clone < 0:
            used[self.winner] = True
        return used


class AdmissionQueue:
    """The master's admission queue, factored transport-agnostic.

    Orders waiting requests under a :class:`QueuePolicy` discipline —
    ``'fifo'`` (arrival order), ``'priority'`` (larger
    :attr:`Request.priority` first, ties FIFO), ``'edf'`` (earliest
    :attr:`Request.deadline` first, ties FIFO), or ``'wfq'`` (weighted fair
    queueing: per-:attr:`Request.slo` FIFO lanes, stride-scheduled by
    ``QueuePolicy.class_weights`` so backlogged classes share pops in
    weight proportion and none starves).  It holds NO clock and NO
    dispatch state, so the same class backs both the simulated-clock
    :class:`EventDrivenMaster` and the wall-clock
    :class:`repro.cluster.coordinator.ClusterCoordinator` (drop-on-expiry
    stays with the caller, who owns the clock).

    >>> q = AdmissionQueue(QueuePolicy(discipline="edf"))
    >>> q.push(Request(request_id=0, arrival=0.0, deadline=9.0))
    >>> q.push(Request(request_id=1, arrival=1.0, deadline=2.0))
    >>> q.pop().request_id, len(q)
    (1, 1)
    """

    def __init__(self, policy: QueuePolicy):
        self.policy = policy
        self._queue: deque[Request] = deque()  # fifo order
        self._prio: list = []  # (key, Request) heap: 'priority'/'edf' order
        self._queued_ids: set[int] = set()
        # oldest-waiting lookup (max_wait timers): lazily-cleaned min-heap,
        # valid under every discipline (pops leave stale entries behind)
        self._arrival_heap: list[tuple[float, int]] = []
        # 'wfq' state: per-class FIFO lanes + stride-scheduler pass values
        self._lanes: dict[str, deque[Request]] = {}
        self._pass: dict[str, float] = {}
        self._vclock = 0.0  # pass of the most recently popped class
        self._weights = dict(policy.class_weights or ())

    def __len__(self) -> int:
        return len(self._queued_ids)

    def __contains__(self, request_id: int) -> bool:
        return request_id in self._queued_ids

    def _key(self, req: Request) -> tuple:
        if self.policy.discipline == "priority":
            return (-req.priority, req.arrival, req.request_id)
        return (req.deadline, req.arrival, req.request_id)  # 'edf'

    def push(self, req: Request) -> None:
        if self.policy.discipline == "fifo":
            self._queue.append(req)
        elif self.policy.discipline == "wfq":
            lane = self._lanes.setdefault(req.slo, deque())
            if not lane:
                # a class (re)activating joins at the current virtual time:
                # it cannot burst ahead on pass credit accrued while idle
                self._pass[req.slo] = max(
                    self._pass.get(req.slo, 0.0), self._vclock
                )
            lane.append(req)
        else:
            heapq.heappush(self._prio, (self._key(req), req))
        self._queued_ids.add(req.request_id)
        heapq.heappush(self._arrival_heap, (req.arrival, req.request_id))

    def _pop_wfq(self) -> Request:
        best = None
        for name, lane in self._lanes.items():
            if not lane:
                continue
            key = (self._pass[name], lane[0].arrival, name)
            if best is None or key < best:
                best = key
        name = best[2]
        req = self._lanes[name].popleft()
        self._vclock = self._pass[name]
        self._pass[name] += 1.0 / self._weights.get(name, 1.0)
        return req

    def pop(self) -> Request:
        if self.policy.discipline == "fifo":
            req = self._queue.popleft()
        elif self.policy.discipline == "wfq":
            req = self._pop_wfq()
        else:
            req = heapq.heappop(self._prio)[1]
        self._queued_ids.discard(req.request_id)
        return req

    def oldest_arrival(self) -> float:
        """Arrival time of the longest-waiting queued request (``inf`` when
        empty) — the quantity ``max_wait`` formation timers key on."""
        h = self._arrival_heap
        while h and h[0][1] not in self._queued_ids:
            heapq.heappop(h)
        return h[0][0] if h else math.inf

    def evict_for(self, req: Request) -> Optional[Request]:
        """Pick a queued victim to shed so an arriving ``req`` can be
        admitted at capacity (weight-aware load shedding).

        Under ``'wfq'``: the NEWEST request of the cheapest backlogged
        class (smallest weight, ties by name) is evicted — but only when
        its class weighs strictly less than ``req``'s, so equal-weight
        classes never evict each other and the newcomer is shed instead
        (``None``).  Under every other discipline the queue has no class
        structure, so the newcomer is always the victim (``None`` — plain
        tail drop).
        """
        if self.policy.discipline != "wfq":
            return None
        w_new = self._weights.get(req.slo, 1.0)
        best = None
        for name, lane in self._lanes.items():
            if not lane:
                continue
            key = (self._weights.get(name, 1.0), name)
            if best is None or key < best:
                best = key
        if best is None or best[0] >= w_new:
            return None
        victim = self._lanes[best[1]].pop()
        self._queued_ids.discard(victim.request_id)
        return victim


def late_threshold(
    policy: StragglerPolicy,
    job: "BatchJob",
    service_window: Sequence[float],
) -> Optional[float]:
    """Lateness threshold for one job under a trigger-driven policy.

    Caller-supplied ``policy.threshold`` model first, else the empirical
    ``late_quantile`` of the caller's window of observed batch service
    times once ``min_observations`` have accumulated, else None (not yet
    calibrated -> no trigger).  Shared by the simulated master and the
    wall-clock cluster coordinator, so both calibrate identically.
    """
    if policy.threshold is not None:
        return float(policy.threshold(job))
    if len(service_window) >= policy.min_observations:
        return float(
            np.quantile(np.asarray(service_window), policy.late_quantile)
        )
    return None


def job_observations(job: "BatchJob") -> list[tuple[np.ndarray, np.ndarray]]:
    """Censoring-correct telemetry of one completed job: (times, censored).

    Cancelled replicas are only OBSERVED up to their cancellation instant —
    recording them censored AT that bound keeps a censored MLE unbiased
    (their full would-have-been draws would drag the fitted rate down by
    the censoring fraction).  Covers all three attempt records:

    * the live attempt (winner uncensored; a relaunched job's live draws
      censor at :attr:`BatchJob.attempt_service`, not the full sojourn);
    * relaunch-discarded attempts (every replica censored at its relaunch
      instant);
    * speculative clones / hedges (censored at THEIR cancellation time;
      only a winning clone's fastest replica is uncensored).

    Times are unnormalized (the caller divides by the batch's work units
    before feeding :meth:`repro.core.tuner.StragglerTuner.observe`).
    """
    used = job.used_mask()
    observed = np.minimum(job.service_times, job.attempt_service)
    out = [(observed, ~used)]
    starts = [job.dispatched, *job.relaunched_at]
    for k, attempt in enumerate(job.discarded_service_times):
        horizon = starts[k + 1] - starts[k]
        out.append(
            (np.minimum(attempt, horizon), np.ones(len(attempt), dtype=bool))
        )
    for k in range(job.n_clones):
        clone_cancel = job.completed - job.clone_dispatched[k]
        clone_times = job.clone_service_times[k]
        clone_used = np.zeros(len(clone_times), dtype=bool)
        if job.winner_clone == k:
            clone_used[int(np.argmin(clone_times))] = True
        out.append((np.minimum(clone_times, clone_cancel), ~clone_used))
    return out


# sampler(job, group) -> per-replica service times for dispatching `job` on
# replica-set `group` (clone dispatches use the same sampler)
ServiceSampler = Callable[[BatchJob, int], np.ndarray]
# callback(job) -> None, or {'n_groups': int, 'service_sampler': fn?} to
# request a drain-then-reconfigure
JobCallback = Callable[[BatchJob], Optional[dict]]


class EventDrivenMaster:
    """The serving master as a discrete-event system (see module docstring).

    >>> master = EventDrivenMaster(2, lambda job, g: np.array([0.5, 1.0]))
    >>> master.submit(Request(request_id=0, arrival=0.0))
    >>> jobs = master.run()
    >>> jobs[0].requests[0].sojourn
    0.5
    """

    def __init__(
        self,
        n_groups: int,
        service_sampler: ServiceSampler,
        policy: Optional[QueuePolicy] = None,
        clock: float = 0.0,
        on_job_complete: Optional[JobCallback] = None,
        speculation: Optional[StragglerPolicy] = None,
        on_drop: Optional[Callable[[Request], None]] = None,
        straggler_policy: Optional[StragglerPolicy] = None,
    ):
        if n_groups < 1:
            raise ValueError(f"n_groups must be >= 1, got {n_groups}")
        if speculation is not None and straggler_policy is not None:
            raise ValueError(
                "pass either speculation= or its alias straggler_policy=, "
                "not both"
            )
        self.n_groups = n_groups
        self.policy = policy or QueuePolicy()
        self.speculation = (
            speculation if speculation is not None else straggler_policy
        )
        self._sampler = service_sampler
        self.clock = float(clock)
        self.on_job_complete = on_job_complete
        # fires the moment drop-on-expiry sheds a request, so SLO telemetry
        # reaches re-plan triggers DURING the stream, not after it ends
        self.on_drop = on_drop
        self._events: list = []  # (time, seq, kind, payload)
        self._seq = itertools.count()
        self._admission = AdmissionQueue(self.policy)
        # formed batches awaiting an idle set: FIFO, or (under 'priority' /
        # 'edf') a heap keyed so the most urgent batch overtakes
        # earlier-formed ones at dispatch
        self._pending: list = []
        self._idle: list[int] = list(range(n_groups))
        heapq.heapify(self._idle)
        self._in_flight: dict[int, BatchJob] = {}
        self._batch_seq = itertools.count()
        self._timer_due = math.inf  # earliest pending max_wait timer
        self._reconfig: Optional[dict] = None
        self.completed_jobs: list[BatchJob] = []
        self.dropped_requests: list[Request] = []
        self.reconfigurations = 0
        self.speculations = 0  # clones actually launched
        self.relaunches = 0  # late attempts cancelled + re-drawn
        self.hedges = 0  # extra sets taken at dispatch time
        self._hedge_count = 0  # dispatch counter driving the hedge stride
        # observed batch service times: the empirical late-threshold fallback
        self._service_window: deque[float] = deque(maxlen=64)

    # -- submission ----------------------------------------------------------
    def submit(self, request: Request) -> None:
        """Admit one request at its arrival time (admission + formation
        policies apply)."""
        self._push(request.arrival, "arrival", request)

    def submit_formed(
        self,
        requests: Sequence[Request],
        at: Optional[float] = None,
        service_times: Optional[np.ndarray] = None,
    ) -> BatchJob:
        """Enqueue a PRE-FORMED batch, bypassing admission and formation.

        The compatibility shim uses this to drive one synchronized round:
        ``service_times`` (per-replica) may be pre-drawn so the shim's RNG
        stream matches the legacy engine draw-for-draw.
        """
        t = self.clock if at is None else float(at)
        job = BatchJob(
            batch_id=next(self._batch_seq),
            requests=tuple(requests),
            formed_at=t,
        )
        if service_times is not None:
            job.service_times = np.asarray(service_times, dtype=float)
        self._push(t, "formed", job)
        return job

    # -- event loop ----------------------------------------------------------
    def run(self) -> list[BatchJob]:
        """Process events until every submitted request has completed."""
        while True:
            self._try_dispatch()
            if not self._events:
                if self._n_queued():
                    # arrival stream ended with a partial batch waiting:
                    # flush it (in max_batch_size chunks) rather than strand it
                    while self._n_queued():
                        self._form(min(self._n_queued(), self.policy.max_batch_size))
                    continue
                if self._pending or self._in_flight:
                    # in-flight batches always hold a depart event, and
                    # pending batches with every set idle dispatch above —
                    # reaching here means a reconfig drain resolves next lap
                    continue
                break
            t, _, kind, payload = heapq.heappop(self._events)
            self.clock = max(self.clock, t)
            if kind == "arrival":
                self._on_arrival(payload)
            elif kind == "timer":
                self._on_timer(payload)
            elif kind == "formed":
                self._pending_push(payload)
            elif kind == "depart":
                self._on_depart(payload)
            elif kind == "spec":
                self._on_spec(payload)
        return self.completed_jobs

    # -- internals -----------------------------------------------------------
    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (float(t), next(self._seq), kind, payload))

    def _n_queued(self) -> int:
        return len(self._admission)

    def _drop(self, req: Request) -> None:
        req.dropped = True
        self.dropped_requests.append(req)
        if self.on_drop is not None:
            self.on_drop(req)

    def _on_arrival(self, req: Request) -> None:
        if self.policy.drop_expired and req.deadline < req.arrival:
            # already expired at admission: never queue dead work
            self._drop(req)
            return
        cap = self.policy.queue_cap
        if cap is not None and self._n_queued() >= cap:
            # admission-control shedding: the queue is at capacity.  Under
            # 'wfq' a heavier-class arrival evicts the newest request of
            # the cheapest backlogged class instead of being shed itself.
            victim = self._admission.evict_for(req)
            if victim is None:
                self._drop(req)
                return
            self._drop(victim)
        self._admission.push(req)
        self._maybe_form()
        self._arm_wait_timer()

    def _maybe_form(self) -> None:
        """Size-triggered formation, throttled under admission control.

        Without a ``queue_cap`` formation is eager: every
        ``max_batch_size``-full queue forms immediately (formed batches
        buffer unboundedly awaiting idle sets).  With a cap, eager
        formation would drain the admission queue into that unbounded
        buffer and make the cap cosmetic — overload backlog must stay IN
        the admission queue, where the cap and WFQ eviction act.  So
        size-triggered formation only runs while fewer than ``n_groups``
        batches await dispatch; ``max_wait`` timers and the end-of-stream
        flush bypass the throttle, so the oldest-waiting bound holds
        regardless.  Re-checked on every departure (freed capacity pulls
        queued work forward).
        """
        while self._n_queued() >= self.policy.max_batch_size:
            if (
                self.policy.queue_cap is not None
                and len(self._pending) >= self.n_groups
            ):
                return
            self._form(self.policy.max_batch_size)

    def _arm_wait_timer(self) -> None:
        """Keep ONE formation timer armed at ``oldest_arrival + max_wait``.

        Oldest-waiting semantics: the timer tracks the longest-waiting
        QUEUED request (not a per-request deadline), so the ``max_wait``
        bound holds under disciplines whose pop order is not arrival order.
        ``_timer_due`` dedupes — a timer already pending at or before the
        due time is reused; stale timers re-check and re-arm harmlessly.
        """
        if not math.isfinite(self.policy.max_wait) or not self._n_queued():
            return
        due = self._admission.oldest_arrival() + self.policy.max_wait
        if due < self._timer_due:
            self._timer_due = due
            self._push(due, "timer", None)

    def _on_timer(self, _payload=None) -> None:
        # oldest-waiting formation: fire batches until no queued request
        # has waited max_wait, then re-arm for the new oldest
        self._timer_due = math.inf
        w = self.policy.max_wait
        while (
            self._n_queued()
            and self._admission.oldest_arrival() + w <= self.clock
        ):
            self._form(min(self._n_queued(), self.policy.max_batch_size))
        self._arm_wait_timer()

    def _pop_request(self) -> Request:
        return self._admission.pop()

    def _pending_key(self, job: BatchJob) -> tuple:
        if self.policy.discipline == "priority":
            return (-job.priority, job.batch_id)
        return (job.deadline, job.batch_id)  # 'edf'

    def _pending_push(self, job: BatchJob) -> None:
        if self.policy.discipline in ("priority", "edf"):
            heapq.heappush(self._pending, (self._pending_key(job), job))
        else:
            self._pending.append(job)

    def _pending_pop(self) -> BatchJob:
        if self.policy.discipline in ("priority", "edf"):
            return heapq.heappop(self._pending)[1]
        return self._pending.pop(0)

    def _form(self, k: int) -> None:
        reqs = []
        for _ in range(k):
            req = self._pop_request()
            if self.policy.drop_expired and req.deadline < self.clock:
                # expired while queued: shed at the formation boundary
                self._drop(req)
            else:
                reqs.append(req)
        if not reqs:
            return  # everything popped was dead work
        job = BatchJob(
            batch_id=next(self._batch_seq),
            requests=tuple(reqs),
            formed_at=self.clock,
        )
        self._pending_push(job)

    def _spec_threshold(self, job: BatchJob) -> Optional[float]:
        """Lateness threshold for one job (see :func:`late_threshold`)."""
        return late_threshold(self.speculation, job, self._service_window)

    def _arm_speculation(self, job: BatchJob) -> None:
        """Schedule the late-response check for a just-(re)dispatched job.

        Only the trigger-driven policies (clone, relaunch) arm; hedging
        acts at dispatch time and NoOp never acts.
        """
        pol = self.speculation
        if isinstance(pol, ClonePolicy):
            if pol.max_clones <= job.n_clones:
                return
        elif isinstance(pol, RelaunchPolicy):
            if pol.max_relaunches <= job.n_relaunches:
                return
        else:
            return
        threshold = self._spec_threshold(job)
        if threshold is not None and math.isfinite(threshold) and threshold > 0:
            self._push(self.clock + threshold, "spec", job)

    def _hedge_selected(self) -> bool:
        """Deterministic stride over dispatches: job n is hedged iff
        floor((n+1)f) > floor(nf), hitting exactly a ``hedge_fraction`` of
        jobs with no RNG (reproducible, CRN-friendly)."""
        f = self.speculation.hedge_fraction
        n = self._hedge_count
        self._hedge_count += 1
        return math.floor((n + 1) * f) > math.floor(n * f)

    def _try_dispatch(self) -> None:
        if self._reconfig is not None:
            if self._in_flight:
                return  # draining: no new dispatches until the fabric quiesces
            self._apply_reconfig()
        while self._pending and self._idle:
            job = self._pending_pop()
            group = heapq.heappop(self._idle)
            job.group = group
            job.dispatched = self.clock
            if job.service_times is None:
                job.service_times = np.asarray(
                    self._sampler(job, group), dtype=float
                )
            job.winner = int(np.argmin(job.service_times))
            # first-replica-wins: the set frees at the winner's response and
            # the remaining replicas are cancelled
            job.completed = self.clock + float(job.service_times[job.winner])
            self._in_flight[group] = job
            if (
                isinstance(self.speculation, HedgedDispatchPolicy)
                and self._hedge_selected()
            ):
                # hedged dispatch: grab up to k-1 ADDITIONAL idle sets now,
                # racing from t=0 (idle-only, queued work never displaced)
                for _ in range(self.speculation.k - 1):
                    if not self._idle:
                        break
                    g2 = heapq.heappop(self._idle)
                    times = np.asarray(self._sampler(job, g2), dtype=float)
                    job.clone_groups.append(g2)
                    job.clone_dispatched.append(self.clock)
                    job.clone_service_times.append(times)
                    self._in_flight[g2] = job
                    self.hedges += 1
                    done = self.clock + float(times.min())
                    if done < job.completed:
                        job.completed = done
                        job.winner_clone = job.n_clones - 1
            self._push(job.completed, "depart", job)
            self._arm_speculation(job)

    def _on_spec(self, job: BatchJob) -> None:
        """Late-response check: the job's first response has not arrived by
        the policy threshold -> clone onto an idle set, or relaunch."""
        if job.departed or job.completed <= self.clock:
            return  # the original responded first: the trigger is a no-op
        if self._reconfig is not None:
            return  # draining: never grow/redraw the in-flight footprint
        if isinstance(self.speculation, RelaunchPolicy):
            self._relaunch(job)
            return
        if job.n_clones >= self.speculation.max_clones:
            return  # clone budget exhausted
        if self._idle:
            group = heapq.heappop(self._idle)
            times = np.asarray(self._sampler(job, group), dtype=float)
            job.clone_groups.append(group)
            job.clone_dispatched.append(self.clock)
            job.clone_service_times.append(times)
            self._in_flight[group] = job
            self.speculations += 1
            clone_done = self.clock + float(times.min())
            if clone_done < job.completed:
                # the clone wins the race: complete earlier and cancel the
                # originals (the old depart event is ignored via `departed`)
                job.completed = clone_done
                job.winner_clone = job.n_clones - 1
                self._push(job.completed, "depart", job)
        # re-arm while budget remains (also covers "no idle set right now")
        self._arm_speculation(job)

    def _relaunch(self, job: BatchJob) -> None:
        """Cancel the job's in-flight attempt and re-dispatch it fresh on
        the SAME replica-set (no extra capacity; the cancelled attempt is
        kept, censored at the relaunch instant, for telemetry)."""
        if job.n_relaunches >= self.speculation.max_relaunches:
            return  # relaunch budget exhausted
        job.discarded_service_times.append(job.service_times)
        job.relaunched_at.append(self.clock)
        job.service_times = np.asarray(
            self._sampler(job, job.group), dtype=float
        )
        job.winner = int(np.argmin(job.service_times))
        # the fresh attempt may finish LATER than the cancelled one would
        # have; the old depart event is skipped by the completed > clock
        # stale guard in _on_depart
        job.completed = self.clock + float(job.service_times[job.winner])
        self.relaunches += 1
        self._push(job.completed, "depart", job)
        self._arm_speculation(job)

    def _on_depart(self, job: BatchJob) -> None:
        if job.departed or job.completed > self.clock:
            # stale event: a winning clone already departed this job, or a
            # relaunch moved its completion past this event's time
            return
        job.departed = True
        for group in job.groups:
            del self._in_flight[group]
            # with a reconfig pending, freed sets are NOT re-added — the
            # whole fabric is rebuilt at the quiesce point in _apply_reconfig
            if self._reconfig is None:
                heapq.heappush(self._idle, group)
        for req in job.requests:
            req.batch_id = job.batch_id
            req.dispatched = job.dispatched
            req.completion = job.completed
        self.completed_jobs.append(job)
        self._service_window.append(job.service)
        # freed capacity pulls throttled queued work forward (no-op unless
        # a queue_cap armed the formation throttle)
        self._maybe_form()
        self._arm_wait_timer()
        # every completed job reports (model work + telemetry happen in the
        # callback), including those draining out; a newer reconfig request
        # supersedes the pending one at the same quiesce point
        if self.on_job_complete is not None:
            rc = self.on_job_complete(job)
            if rc:
                self._reconfig = dict(rc)

    def swap_policy(self, new: QueuePolicy) -> None:
        """Swap the live queue policy in place (serving re-plan adoption).

        Only the scalar knobs may move — ``max_wait``, ``queue_cap``,
        ``drop_expired``, ``max_batch_size``; the admission structure
        (discipline, class weights = WFQ lane state) must survive the swap,
        so changing either raises.  A shorter ``max_wait`` re-arms the
        formation timer against the oldest queued request immediately, and
        a loosened cap/size pulls queued work forward through the
        (possibly throttled) size trigger.
        """
        if (
            new.discipline != self.policy.discipline
            or new.class_weights != self.policy.class_weights
        ):
            raise ValueError(
                "cannot change the queue discipline or class weights on a "
                "live master (queued lane state would be orphaned)"
            )
        self.policy = new
        self._admission.policy = new
        self._maybe_form()
        self._arm_wait_timer()

    def _apply_reconfig(self) -> None:
        rc, self._reconfig = self._reconfig, None
        self.n_groups = int(rc.get("n_groups", self.n_groups))
        if self.n_groups < 1:
            raise ValueError(f"reconfig n_groups must be >= 1, got {self.n_groups}")
        if "service_sampler" in rc:
            self._sampler = rc["service_sampler"]
        if "policy" in rc:
            self.swap_policy(rc["policy"])
        self._idle = list(range(self.n_groups))
        heapq.heapify(self._idle)
        self.reconfigurations += 1
