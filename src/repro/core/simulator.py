"""Monte-Carlo simulator of the paper's System1.

Two modes:

* :func:`simulate_maxmin` — the paper's completion rule for non-overlapping
  balanced replication, fully vectorized: ``T = max_i min_j T_ij``.
* :func:`simulate_coverage` — general rule for ANY :class:`Assignment`
  (overlapping, unbalanced): completion is the first time the union of
  finished workers' batches covers the dataset.  Vectorized over trials via a
  sort + running-coverage scan.

Service times are drawn per (worker) from the size-dependent model: a worker
serving ``s`` units draws from ``dist.scaled(s)``.

Also provides :class:`StepTimeSimulator` — the runtime-facing generator of
per-step, per-worker service times (with optional persistent slow nodes and
transient failures) used by the fault-tolerance harness and the tuner tests.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .order_stats import ServiceDistribution
from .policies import Assignment, balanced_nonoverlapping

__all__ = [
    "SimResult",
    "simulate_maxmin",
    "simulate_coverage",
    "StepTimeSimulator",
    "FaultEvent",
]


@dataclasses.dataclass(frozen=True)
class SimResult:
    samples: np.ndarray  # (n_trials,) completion times

    @property
    def mean(self) -> float:
        return float(self.samples.mean())

    @property
    def var(self) -> float:
        return float(self.samples.var(ddof=1))

    @property
    def std(self) -> float:
        return float(self.samples.std(ddof=1))

    def quantile(self, q: float) -> float:
        return float(np.quantile(self.samples, q))

    @property
    def stderr(self) -> float:
        return float(self.samples.std(ddof=1) / np.sqrt(len(self.samples)))


def simulate_maxmin(
    dist: ServiceDistribution,
    n_workers: int,
    n_batches: int,
    n_trials: int = 20_000,
    seed: int = 0,
) -> SimResult:
    """Completion time of balanced non-overlapping replication (fast path)."""
    if n_workers % n_batches:
        raise ValueError(f"B={n_batches} must divide N={n_workers}")
    r = n_workers // n_batches
    per_batch = dist.scaled(n_workers / n_batches)
    rng = np.random.default_rng(seed)
    t = per_batch.sample(rng, (n_trials, n_batches, r))
    completion = t.min(axis=2).max(axis=1)
    return SimResult(completion)


def simulate_coverage(
    dist: ServiceDistribution,
    assignment: Assignment,
    n_trials: int = 20_000,
    seed: int = 0,
) -> SimResult:
    """Completion time under the coverage rule for arbitrary assignments.

    Vectorized: draw all worker times, argsort per trial, walk the sorted
    order accumulating covered units, record the time when coverage hits N.
    The walk is a python loop over workers (N is small, <=64) but vectorized
    over trials.
    """
    rng = np.random.default_rng(seed)
    loads = assignment.worker_load()  # (N,)
    n = assignment.n_workers
    # scaled sampling: worker j draws from dist.scaled(load_j)
    base = dist.scaled(1.0)
    # sample unit-load times then rescale: for Exp/SExp, scaled(s) is an
    # affine transform of the unit draw ONLY for Exp (rate mu/s <=> s * unit
    # draw).  SExp(s*Delta, mu/s) = s * SExp(Delta, mu) likewise.  So we can
    # draw unit times and multiply by the load.
    unit = base.sample(rng, (n_trials, n))
    times = unit * loads[None, :]

    cov = assignment.coverage_matrix()  # (N, units) bool
    order = np.argsort(times, axis=1)  # (trials, N)
    sorted_times = np.take_along_axis(times, order, axis=1)
    completion = np.empty(n_trials, dtype=float)
    # running coverage via bit-packing for speed
    packed = np.packbits(cov, axis=1)  # (N, ceil(units/8)) uint8
    full = np.packbits(np.ones(assignment.n_units, dtype=bool))
    for t in range(n_trials):
        acc = np.zeros_like(full)
        done_time = sorted_times[t, -1]
        for k in range(n):
            acc |= packed[order[t, k]]
            if np.array_equal(acc & full, full):
                done_time = sorted_times[t, k]
                break
        completion[t] = done_time
    return SimResult(completion)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """A scheduled fault: worker ``worker`` is dead during steps
    [start_step, end_step)."""

    worker: int
    start_step: int
    end_step: int


class StepTimeSimulator:
    """Per-step service-time generator for the runtime harness.

    Models three straggler phenomena on top of the base distribution:

    * i.i.d. randomness (the paper's model),
    * persistent slow workers (multiplicative slowdown),
    * transient faults (worker produces no result during the event).

    Returns, per step, an array of service times (np.inf for dead workers).
    """

    def __init__(
        self,
        dist: ServiceDistribution,
        n_workers: int,
        seed: int = 0,
        slow_workers: dict[int, float] | None = None,
        faults: Sequence[FaultEvent] = (),
    ):
        self._dist = dist
        self._n = n_workers
        self._rng = np.random.default_rng(seed)
        self._slow = dict(slow_workers or {})
        for w in self._slow:
            if not 0 <= w < n_workers:
                raise ValueError(f"slow worker id {w} out of range")
        self._faults = list(faults)
        self.step = 0

    def next_step(self, loads: np.ndarray | None = None) -> np.ndarray:
        """Draw one step of per-worker service times.

        ``loads``: units of data per worker (defaults to 1.0 each); service
        scales per the size-dependent model.
        """
        if loads is None:
            loads = np.ones(self._n)
        loads = np.asarray(loads, dtype=float)
        if loads.shape != (self._n,):
            raise ValueError(f"loads shape {loads.shape} != ({self._n},)")
        unit = self._dist.sample(self._rng, (self._n,))
        times = unit * loads
        for w, factor in self._slow.items():
            times[w] *= factor
        for ev in self._faults:
            if ev.start_step <= self.step < ev.end_step:
                times[ev.worker] = np.inf
        self.step += 1
        return times

    def alive_mask(self) -> np.ndarray:
        mask = np.ones(self._n, dtype=bool)
        for ev in self._faults:
            if ev.start_step <= self.step < ev.end_step:
                mask[ev.worker] = False
        return mask


def completion_from_step_times(
    times: np.ndarray, assignment: Assignment
) -> tuple[float, np.ndarray]:
    """Apply the paper's completion rule to one step of worker times.

    Returns (completion_time, used_mask) where used_mask marks the workers
    whose results the master actually consumed (the fastest replica of each
    batch).  Workers with np.inf (dead) are never used; if a batch has no
    finite replica the completion time is inf (job cannot finish -> the
    elastic layer must re-plan).
    """
    b = assignment.n_batches
    used = np.zeros(assignment.n_workers, dtype=bool)
    batch_done = np.full(b, np.inf)
    for batch in range(b):
        members = [j for j, wb in enumerate(assignment.worker_batch) if wb == batch]
        t = times[members]
        k = int(np.argmin(t))
        if np.isfinite(t[k]):
            batch_done[batch] = t[k]
            used[members[k]] = True
    return float(batch_done.max()), used
