"""Replicated data parallelism (RDP) — the paper's technique as a first-class
mesh/collective feature.

The data-parallel extent ``N_d`` of the mesh is factored into
``(replica=r, batch=B)`` with ``B * r = N_d``:

* all ``r`` devices of a *replica group* (fixed batch index) receive the SAME
  microbatch — the balanced non-overlapping assignment of Thm 1;
* the gradient is the mean over the B distinct batches; a batch survives as
  long as ANY of its replicas survives — the paper's ``max-min`` rule;
* replicas are placed OUTERMOST so that on a multi-pod mesh the replica axis
  maps onto the pod axis: replicas of a batch live in different pods, making
  pod loss non-fatal and (in the steady state) removing gradient traffic from
  the slow inter-pod links entirely (identical replicas need no reduction).

Aggregation modes:

* ``psum_all``        — baseline: mean over the full (replica, batch) plane.
* ``weighted``        — straggler-drop weighted psum: dead/dropped devices are
                        masked; per-batch renormalization keeps the estimate
                        an exact mean over surviving batches (unbiased,
                        because replicas hold identical data).
* ``hierarchical``    — steady-state fast path: psum over ``batch`` only
                        (replicas already agree); zero replica-axis traffic.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .order_stats import ServiceDistribution, completion_mean, completion_var
from .policies import divisors

__all__ = [
    "ReplicationPlan",
    "make_rdp_mesh",
    "batch_index_for_data_coord",
    "aggregate_gradients",
    "rdp_data_spec",
]

AggregationMode = Literal["psum_all", "weighted", "hierarchical"]

REPLICA_AXIS = "replica"
BATCH_AXIS = "batch"
MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class ReplicationPlan:
    """Factoring of the data-parallel extent into (batch, replica)."""

    n_data: int  # total data-parallel device extent (incl. pod axis)
    n_batches: int  # B

    def __post_init__(self):
        if self.n_data <= 0 or self.n_batches <= 0:
            raise ValueError(f"invalid plan {self}")
        if self.n_data % self.n_batches:
            raise ValueError(
                f"B={self.n_batches} must divide data extent {self.n_data}"
            )

    @property
    def replication(self) -> int:
        return self.n_data // self.n_batches

    @property
    def is_full_parallelism(self) -> bool:
        return self.n_batches == self.n_data

    @property
    def is_full_diversity(self) -> bool:
        return self.n_batches == 1

    def feasible_alternatives(self) -> list[int]:
        return divisors(self.n_data)

    def expected_step_stats(
        self, dist: ServiceDistribution
    ) -> tuple[float, float]:
        """(mean, var) of the per-step completion time under the paper's
        model, treating the B batches as the paper's batches and r as the
        replication (Thms 2-4)."""
        return (
            completion_mean(dist, self.n_data, self.n_batches),
            completion_var(dist, self.n_data, self.n_batches),
        )


def make_rdp_mesh(
    plan: ReplicationPlan,
    model_parallel: int,
    devices: np.ndarray | None = None,
) -> Mesh:
    """Build a mesh with axes (replica, batch, model).

    ``devices`` defaults to all local devices.  The device order is taken
    pod-major (the order ``jax.devices()`` returns), so with r replicas the
    replica axis strides across the largest blocks — i.e. across pods when
    the physical topology is multi-pod.  Replicas of a batch therefore live
    in different pods (fault isolation + inter-pod traffic elimination).
    """
    if devices is None:
        devices = np.array(jax.devices())
    devices = np.asarray(devices).reshape(-1)
    expected = plan.n_data * model_parallel
    if devices.size != expected:
        raise ValueError(
            f"need {expected} devices for plan {plan} x model={model_parallel}, "
            f"got {devices.size}"
        )
    arr = devices.reshape(plan.replication, plan.n_batches, model_parallel)
    return Mesh(arr, (REPLICA_AXIS, BATCH_AXIS, MODEL_AXIS))


def rdp_data_spec(*trailing) -> P:
    """PartitionSpec for activations under RDP: batch dim is sharded over the
    ``batch`` axis only and REPLICATED over the ``replica`` axis — that is the
    assignment unit: every replica group member sees the same data."""
    return P(BATCH_AXIS, *trailing)


def batch_index_for_data_coord(plan: ReplicationPlan, data_coord: int) -> int:
    """Which batch a flat data-axis coordinate serves (pipeline feed map).

    Flat data coordinates enumerate (replica-major) the (replica, batch)
    grid: coord = replica * B + batch.
    """
    if not 0 <= data_coord < plan.n_data:
        raise ValueError(f"data coord {data_coord} out of range")
    return data_coord % plan.n_batches


def _check_axes(mesh: Mesh) -> None:
    for ax in (REPLICA_AXIS, BATCH_AXIS):
        if ax not in mesh.axis_names:
            raise ValueError(
                f"mesh {mesh.axis_names} lacks required axis {ax!r}; build it "
                "with make_rdp_mesh"
            )


def aggregate_gradients(
    grads,
    alive: jax.Array | None = None,
    mode: AggregationMode = "weighted",
):
    """Aggregate per-device gradients inside a shard_map'd step.

    Must be called INSIDE shard_map over a mesh with (replica, batch) axes.
    ``grads`` is a pytree of local gradient shards (each replica group member
    computed the same batch, so group members agree up to numerical noise).
    ``alive`` is a scalar 0/1 float for this device (1 = contributed).

    Returns the pytree of aggregated gradients, identical on every device,
    equal to the exact mean over surviving batches.  If a whole replica group
    died, its batch is excluded and the mean renormalizes (the job survives —
    cf. the coverage rule); callers can detect total batch loss via the
    returned ``n_batches_used``.
    """
    if mode == "psum_all":
        def agg(g):
            return jax.lax.pmean(g, (REPLICA_AXIS, BATCH_AXIS))
        return jax.tree.map(agg, grads), None

    if mode == "hierarchical":
        # Steady state: replicas hold identical grads; reduce over batch only.
        def agg(g):
            return jax.lax.pmean(g, BATCH_AXIS)
        return jax.tree.map(agg, grads), None

    if mode != "weighted":
        raise ValueError(f"unknown aggregation mode {mode!r}")

    if alive is None:
        alive = jnp.float32(1.0)
    alive = jnp.asarray(alive, jnp.float32)
    # per replica group: how many members contributed
    n_alive_in_group = jax.lax.psum(alive, REPLICA_AXIS)
    group_ok = (n_alive_in_group > 0).astype(jnp.float32)
    # weight for this device inside its group (0 if group empty)
    w_member = jnp.where(n_alive_in_group > 0, alive / jnp.maximum(n_alive_in_group, 1.0), 0.0)
    # number of surviving batches (same value on every device)
    n_batches_used = jax.lax.psum(group_ok, BATCH_AXIS)

    def agg(g):
        g = g.astype(jnp.float32) if jnp.issubdtype(g.dtype, jnp.floating) else g
        # mean within the replica group (survivors only)
        g_group = jax.lax.psum(g * w_member, REPLICA_AXIS)
        # mean over surviving batches
        g_sum = jax.lax.psum(g_group, BATCH_AXIS)
        return g_sum / jnp.maximum(n_batches_used, 1.0)

    return jax.tree.map(agg, grads), n_batches_used


def aggregate_host(
    grads_per_worker: list,
    alive: np.ndarray,
    plan: ReplicationPlan,
    worker_batch=None,
):
    """Host-side (driver-level) reference aggregation for the virtual-pod
    runtime and for tests: numpy pytrees, same semantics as
    :func:`aggregate_gradients` with mode='weighted'.

    ``grads_per_worker[w]`` is the gradient pytree computed by flat data
    coordinate ``w`` (or None if it produced nothing); ``alive[w]`` marks
    contribution.  ``worker_batch`` optionally supplies the active
    worker->batch map (rate-aware placements differ from the replica-major
    coordinate map used by default).  Returns (mean over surviving batches,
    n_batches_used).
    """
    if len(grads_per_worker) != plan.n_data:
        raise ValueError("need one (possibly None) gradient per data coord")
    if worker_batch is None:
        worker_batch = [
            batch_index_for_data_coord(plan, w) for w in range(plan.n_data)
        ]
    elif len(worker_batch) != plan.n_data:
        raise ValueError("worker_batch must map every data coord")
    alive = np.asarray(alive, dtype=bool)
    batch_grads = []
    for b in range(plan.n_batches):
        members = [
            w
            for w in range(plan.n_data)
            if worker_batch[w] == b and alive[w]
            and grads_per_worker[w] is not None
        ]
        if not members:
            continue
        # replicas agree; average anyway for numerical symmetry
        leaves = [jax.tree.leaves(grads_per_worker[w]) for w in members]
        treedef = jax.tree.structure(grads_per_worker[members[0]])
        mean_leaves = [
            functools.reduce(lambda a, c: a + c, parts) / len(members)
            for parts in zip(*leaves)
        ]
        batch_grads.append(jax.tree.unflatten(treedef, mean_leaves))
    if not batch_grads:
        raise RuntimeError("all batches lost — elastic re-plan required")
    treedef = jax.tree.structure(batch_grads[0])
    leaves = [jax.tree.leaves(g) for g in batch_grads]
    mean_leaves = [
        functools.reduce(lambda a, c: a + c, parts) / len(batch_grads)
        for parts in zip(*leaves)
    ]
    return jax.tree.unflatten(treedef, mean_leaves), len(batch_grads)
