"""jit'd public wrapper for the chunked SSD scan kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.ssm_scan.kernel import ssd_scan_kernel_call
from repro.kernels.ssm_scan.ref import ssd_scan_ref

__all__ = ["ssd_scan"]


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def ssd_scan(x, dt, a_log, b, c, d_skip, *, chunk: int = 128,
             impl: str = "pallas", interpret: bool = True):
    """Chunked SSD scan.  x (B,S,H,P); dt (B,S,H); a_log (H,);
    b, c (B,S,G,N); d_skip (H,).  Returns (y, final_state)."""
    if impl == "xla":
        from repro.models.ssm import ssd_chunked

        s = x.shape[1]
        eff = min(chunk, s) if s % chunk else chunk
        if s % eff:
            eff = s
        return ssd_chunked(x, dt, a_log, b, c, d_skip, eff)
    if impl == "ref":
        return ssd_scan_ref(x, dt, a_log, b, c, d_skip)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    s = x.shape[1]
    eff = chunk if s % chunk == 0 else s
    return ssd_scan_kernel_call(
        x, dt, a_log, b, c, d_skip, chunk=eff, interpret=interpret
    )
