#!/usr/bin/env python
"""Docs consistency checker: every `repro.*` symbol referenced by the docs
must resolve, and the documented code examples must run.

Two checks, both CI-gating (see .github/workflows/ci.yml, `docs` job):

1. **Symbol check** — scan README.md and docs/*.md for backticked dotted
   references (`repro.core.planner.ClusterSpec`, `repro.serving.engine
   .ReplicatedServingEngine.run_load`, ...), import the longest importable
   module prefix, and getattr the rest.  A doc that names a symbol that was
   renamed or removed fails the build instead of silently rotting.
2. **Example check** — execute every ```python fenced block in README.md
   and docs/planner_api.md (the files documented as runnable).  Blocks
   whose first line is ``# not-runnable`` are skipped.

Run: PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SYMBOL_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]
RUNNABLE_FILES = [REPO / "README.md", REPO / "docs" / "planner_api.md"]

# a backticked dotted path rooted at the package, e.g. `repro.core.Metric`;
# an optional trailing call/parenthesis is stripped before resolution
SYMBOL_RE = re.compile(r"`(repro(?:\.\w+)+)(?:\(\))?`")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def resolve(path: str) -> bool:
    """Import the longest importable module prefix, getattr the rest."""
    parts = path.split(".")
    for i in range(len(parts), 0, -1):
        modname = ".".join(parts[:i])
        try:
            obj = importlib.import_module(modname)
        except ImportError:
            continue
        try:
            for attr in parts[i:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def check_symbols() -> list[str]:
    errors = []
    for md in SYMBOL_FILES:
        text = md.read_text()
        for ref in sorted(set(SYMBOL_RE.findall(text))):
            if not resolve(ref):
                errors.append(f"{md.relative_to(REPO)}: unresolved `{ref}`")
    return errors


def check_examples() -> list[str]:
    errors = []
    for md in RUNNABLE_FILES:
        for k, block in enumerate(FENCE_RE.findall(md.read_text())):
            if block.lstrip().startswith("# not-runnable"):
                continue
            try:
                exec(compile(block, f"{md.name}[block {k}]", "exec"), {})
            except Exception as exc:  # noqa: BLE001 - report, don't crash
                errors.append(
                    f"{md.relative_to(REPO)} python block {k} failed: "
                    f"{type(exc).__name__}: {exc}"
                )
    return errors


def main() -> int:
    errors = check_symbols() + check_examples()
    for err in errors:
        print(f"FAIL {err}")
    if errors:
        return 1
    n_files = len(SYMBOL_FILES)
    print(f"docs OK: symbols resolve across {n_files} files, examples ran")
    return 0


if __name__ == "__main__":
    sys.exit(main())
