#!/usr/bin/env python
"""Benchmark regression guard: pin the tracked benches against committed
snapshots and fail the nightly build on a >20% latency regression.

For each guarded module (``benchmarks/bench_<name>.py``) this script

1. imports the module and calls its ``run()`` (the same ``(name,
   us_per_call, derived)`` row contract as ``benchmarks/run.py`` — so every
   correctness assertion inside the bench still gates the build);
2. always leaves an inspectable artifact of the fresh run —
   ``benchmarks/BENCH_<name>.json`` under ``--update`` (the committed
   baseline), ``BENCH_<name>.latest.json`` otherwise (gitignored);
3. unless ``--update`` is given, compares each row's ``us_per_call``
   against the committed baseline: a row more than ``--tolerance`` (default
   20%) slower than its baseline FAILS, a row missing from the current run
   FAILS (a silently dropped headline is a regression too), and a row new
   to the current run only warns (commit an updated baseline to start
   tracking it).

Timings are wall-clock and noisy; the 20% band is wide on purpose — the
guard exists to catch algorithmic blowups (a sweep going quadratic, a CRN
matrix being redrawn per cell), not scheduler jitter.

Run:    PYTHONPATH=src python tools/check_bench.py
Update: PYTHONPATH=src python tools/check_bench.py --update   (then commit)
"""

from __future__ import annotations

import argparse
import importlib
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BENCH_DIR = REPO / "benchmarks"

# modules guarded with committed baselines; the rest of benchmarks/run.py
# still runs nightly but is not regression-pinned
GUARDED = (
    "planner", "serving_latency", "cluster", "sweep_kernel", "coding",
    "multitenant",
)


def run_module(name: str) -> list[dict]:
    mod = importlib.import_module(f"benchmarks.bench_{name}")
    return [
        {"name": row, "us_per_call": us, "derived": derived}
        for row, us, derived in mod.run()
    ]


def compare(name: str, baseline: list[dict], fresh: list[dict],
            tolerance: float) -> list[str]:
    errors = []
    base = {r["name"]: r["us_per_call"] for r in baseline}
    seen = set()
    for row in fresh:
        seen.add(row["name"])
        ref = base.get(row["name"])
        if ref is None:
            print(f"NOTE {name}: new row {row['name']} (not in baseline; "
                  f"run --update to pin it)")
            continue
        if row["us_per_call"] > ref * (1.0 + tolerance):
            errors.append(
                f"{name}/{row['name']}: {row['us_per_call']:.1f}us vs "
                f"baseline {ref:.1f}us "
                f"(+{row['us_per_call'] / ref - 1.0:.0%} > "
                f"{tolerance:.0%} tolerance)"
            )
    for missing in sorted(set(base) - seen):
        errors.append(f"{name}/{missing}: row present in baseline but "
                      f"absent from the current run")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="rewrite baselines instead of comparing")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional slowdown per row (default 0.20)")
    args = ap.parse_args()
    sys.path.insert(0, str(REPO))

    errors: list[str] = []
    for name in GUARDED:
        snap = BENCH_DIR / f"BENCH_{name}.json"
        try:
            fresh = run_module(name)
        except Exception as exc:  # noqa: BLE001 - bench assertions gate too
            errors.append(f"{name}: run() raised {type(exc).__name__}: {exc}")
            continue
        if not args.update and snap.exists():
            baseline = json.loads(snap.read_text())["rows"]
            errors.extend(compare(name, baseline, fresh, args.tolerance))
        elif not args.update:
            errors.append(f"{name}: no committed baseline at {snap.name} "
                          f"(run with --update and commit it)")
        out = snap if args.update else snap.with_suffix(".latest.json")
        out.write_text(json.dumps({"module": f"bench_{name}",
                                   "rows": fresh}, indent=2) + "\n")
        print(f"wrote {out.relative_to(REPO)} ({len(fresh)} rows)")

    for err in errors:
        print(f"FAIL {err}")
    if errors:
        return 1
    print(f"bench guard OK: {len(GUARDED)} modules within "
          f"{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
