"""Serving latency under load: sojourn p50/p99/p999 across arrival rate x B,
plus the speculative re-dispatch and EDF/deadline headlines.

The queueing twin of Fig. 2 (and the paper's Thm 4 serving story): a fleet
of N server groups factored into B replica-sets serves Poisson batch-job
traffic; each cell reports per-request SOJOURN (queue wait + service)
quantiles from the discrete-event queueing model — one shared CRN draw
matrix + arrival sequence per utilization row (core.simulator.sweep_sojourn).

Tracked nightly so the latency trajectory is pinned like planner overhead:

* zero-load anchor: sojourn collapses to pure service, whose p99-optimal B
  matches the batch-completion story;
* under load (u = 0.7) the load-aware planner's p99 pick must beat BOTH the
  batch-completion-optimal B and the no-replication baseline (B = N, r = 1);
* **speculation sweep** (heavy-shift SExp fleet, u = 0.7): static
  replication is unaffordable there — the shift makes every r >= 2 split
  unstable — so the planner's (B, late-quantile) pick clones only
  stragglers.  Asserted: the speculative pick's MEASURED p99 sojourn beats
  no-speculation at the same B and beats EVERY pure-B split of the same
  16-worker fleet (the Aktaş et al. clone-attack headline at equal worker
  budget);
* **EDF vs FIFO** (B = 4, u = 0.7, 25% tight / 75% loose deadlines):
  earliest-deadline-first admission must lower the deadline-miss rate vs
  FIFO at the same load;
* **policy regime crossover** (Behrouzi-Far & Soljanin 2020): offered the
  full clone/relaunch/hedged portfolio, the planner's pick flips with the
  service regime — memoryless Exp service at high utilization lands on a
  trigger-driven policy or plain replication (never hedged dispatch, which
  only burns capacity when every draw is exchangeable), while the
  heavy-shift SExp fleet at moderate utilization lands on clone/hedged
  (redundancy pays when the shift dominates).  The online twin: a
  StragglerTuner fed Exp telemetry, then heavy-shift telemetry, switches
  its adopted policy kind across the drift (asserted per run at the fixed
  seed; verified 15/15 dev seeds at these exact settings).
"""

import time

import numpy as np

from repro.core import (
    ClusterSpec,
    Exponential,
    Objective,
    PolicyCandidate,
    ReplicationPlan,
    ShiftedExponential,
    SimulatedPlanner,
    StragglerTuner,
    TunerConfig,
    simulate_sojourn,
)
from repro.serving import ReplicatedServingEngine, ServeEngineConfig


def _engine_run(
    dist, n, b, util, seed=42, jobs=6_000, speculation=None,
    discipline="fifo", deadlines=None,
):
    eng = ReplicatedServingEngine(ServeEngineConfig(
        n_server_groups=n, n_batches=b, batch_size=4, prompt_len=16,
        gen_tokens=8, delta=dist.delta, mu=dist.mu, utilization=util,
        execute_model=False, seed=seed, speculation_quantile=speculation,
        queue_discipline=discipline,
    ))
    return eng.run_load(n_requests=jobs, deadlines=deadlines)


def run(n=16, jobs=6_000):
    dist = ShiftedExponential(delta=0.02, mu=2.0)  # Fig. 2-style SExp fleet
    spec = ClusterSpec(n_workers=n, dist=dist)
    planner = SimulatedPlanner(n_trials=jobs, seed=0)
    batch_b = planner.plan(spec, Objective(metric="p99")).n_batches

    rows = []
    t0 = time.perf_counter()
    cells = 0
    derived = [f"batch_completion_p99_B*={batch_b}"]
    for util in (0.3, 0.7, 0.9):
        objective = Objective(metric="p99", utilization=util)
        plan = planner.plan(spec, objective)
        rate = objective.offered_rate(spec)
        # measured sojourn at an independent seed (not the planner's draws)
        measured = {}
        for b in sorted({1, plan.n_batches, batch_b, n}):
            sim = simulate_sojourn(
                dist, n, b, arrival_rate=rate, n_jobs=jobs, seed=123
            )
            measured[b] = (
                sim.quantile(0.50), sim.quantile(0.99), sim.quantile(0.999)
            )
            cells += 1
        if util == 0.7:
            # acceptance: the load-aware pick beats batch-completion-optimal
            # AND no-replication on MEASURED p99 (see tests/test_queueing.py)
            assert measured[plan.n_batches][1] < measured[batch_b][1]
            assert measured[plan.n_batches][1] < measured[n][1]
        derived.append(
            f"u={util:g}:B*={plan.n_batches};"
            + ";".join(
                f"B{b}:p50={p50*1e3:.0f}ms,p99={p99*1e3:.0f}ms,"
                f"p999={p999*1e3:.0f}ms"
                for b, (p50, p99, p999) in measured.items()
            )
        )
    dt = (time.perf_counter() - t0) / max(cells, 1)
    rows.append(("serving_sojourn_latency", dt * 1e6, "|".join(derived)))

    # -- speculation sweep: clone-attack vs pure replication ------------------
    # Heavy-shift fleet: the deterministic part of the service time is paid
    # per replica-set but never shrunk by redundancy, so at u = 0.7 every
    # r >= 2 split is past saturation and the only affordable redundancy is
    # SPECULATIVE (clone a batch onto an idle set when its first response is
    # past the late-quantile of the fitted first-response distribution).
    heavy = ShiftedExponential(delta=0.5, mu=2.0)
    heavy_spec = ClusterSpec(n_workers=n, dist=heavy)
    t0 = time.perf_counter()
    spec_plan = SimulatedPlanner(n_trials=jobs, seed=0).plan(
        heavy_spec,
        Objective(
            metric="p99", utilization=0.7,
            speculation_quantiles=(0.8, 0.9, 0.95),
        ),
    )
    b_s, q_s = spec_plan.n_batches, spec_plan.speculation_quantile
    assert q_s is not None, "planner should choose to speculate on this fleet"
    # engine-measured (independent seed): every pure-B split vs the pick
    pure = {
        b: _engine_run(heavy, n, b, 0.7, jobs=jobs)["p99_sojourn"]
        for b in (1, 2, 4, 8, n)
    }
    spec_run = _engine_run(heavy, n, b_s, 0.7, jobs=jobs, speculation=q_s)
    spec_p99 = spec_run["p99_sojourn"]
    # the headline: late-quantile speculation beats no-speculation at the
    # same B AND every pure-B replication level at equal worker budget
    assert spec_p99 < pure[b_s], (spec_p99, pure[b_s])
    assert spec_p99 < min(pure.values()), (spec_p99, pure)
    dt = (time.perf_counter() - t0) / (len(pure) + 1)
    rows.append((
        "serving_speculation_p99", dt * 1e6,
        f"B*={b_s};q*={q_s};spec_p99={spec_p99*1e3:.0f}ms;"
        f"clones={spec_run['speculations']};"
        + ";".join(f"pureB{b}={p*1e3:.0f}ms" for b, p in pure.items()),
    ))

    # -- EDF vs FIFO: deadline-miss rate at equal load ------------------------
    # B = 4 on the light-shift fleet (the load-aware pick at u = 0.7): the
    # queue is deep enough that admission ORDER matters.  25% of requests
    # carry a tight relative deadline, 75% a loose one; EDF forms the tight
    # ones into earlier batches and must lower the overall miss rate.
    t0 = time.perf_counter()
    rng = np.random.default_rng(777)
    deadlines = np.where(rng.random(jobs) < 0.25, 0.5, 5.0)
    miss = {
        d: _engine_run(
            dist, n, 4, 0.7, jobs=jobs, discipline=d, deadlines=deadlines
        )["deadline_miss_rate"]
        for d in ("fifo", "edf")
    }
    assert miss["edf"] < miss["fifo"], miss
    dt = (time.perf_counter() - t0) / 2
    rows.append((
        "serving_edf_miss_rate", dt * 1e6,
        f"fifo={miss['fifo']:.4f};edf={miss['edf']:.4f}",
    ))

    # -- straggler-policy regime crossover ------------------------------------
    # One portfolio, two fleets: every (B, candidate) cell shares the CRN
    # draw matrix, so the pick is deterministic at the fixed seed.
    portfolio = (
        *(PolicyCandidate("clone", quantile=q) for q in (0.8, 0.9, 0.95)),
        *(PolicyCandidate("relaunch", quantile=q) for q in (0.8, 0.9, 0.95)),
        *(PolicyCandidate("hedged", hedge_fraction=f) for f in (0.1, 0.3)),
    )
    exp_spec = ClusterSpec(n_workers=n, dist=Exponential(mu=2.0))
    t0 = time.perf_counter()
    pplanner = SimulatedPlanner(n_trials=10_000, seed=0)
    exp_plan = pplanner.plan(
        exp_spec,
        Objective(metric="p99", utilization=0.85, policies=portfolio),
    )
    # memoryless service: redundancy-at-dispatch never pays at high load
    assert exp_plan.policy.kind in ("clone", "relaunch", "none"), exp_plan.policy
    heavy_plan = pplanner.plan(
        heavy_spec,
        Objective(metric="p99", utilization=0.45, policies=portfolio),
    )
    # shift-dominated service: redundancy (cloning/hedging) is the win
    assert heavy_plan.policy.kind in ("clone", "hedged"), heavy_plan.policy
    dt = (time.perf_counter() - t0) / 2
    rows.append((
        "serving_policy_crossover", dt * 1e6,
        f"exp:B*={exp_plan.n_batches},policy={exp_plan.policy.kind};"
        f"heavy:B*={heavy_plan.n_batches},policy={heavy_plan.policy.kind}",
    ))

    # -- online policy switch across a service-regime drift -------------------
    # The tuner observes an Exp fleet, adopts a policy, then the fleet
    # drifts heavy-shift (the observation window turns over) and the next
    # re-plans must land on a different, redundancy-type policy.
    t0 = time.perf_counter()
    switch_pols = (
        *(PolicyCandidate("clone", quantile=q) for q in (0.8, 0.9)),
        *(PolicyCandidate("relaunch", quantile=q) for q in (0.8, 0.9)),
        PolicyCandidate("hedged", hedge_fraction=0.1),
        PolicyCandidate("hedged", hedge_fraction=0.3),
    )
    tuner = StragglerTuner(
        ReplicationPlan(n_data=n, n_batches=4),
        TunerConfig(
            mode="simulate", sim_trials=4_000, sim_seed=0, min_samples=64,
            cooldown_steps=8, window_steps=16, improvement_threshold=0.05,
            metric="p99",
        ),
        policy_candidates=switch_pols,
    )
    rng = np.random.default_rng(0)

    def drive(dist_, steps):
        last = None
        for _ in range(steps):
            tuner.observe(dist_.sample(rng, n))
            tuner.observe_load(13.0)
            rp = tuner.maybe_replan()
            if rp is not None:
                tuner.apply(rp)
            if tuner.last_plan is not None:
                last = tuner.last_plan.policy
        return last

    pol_exp = drive(Exponential(mu=2.0), 24)
    pol_heavy = drive(heavy, 32)
    assert pol_exp is not None and pol_exp.kind != "hedged", pol_exp
    assert pol_heavy is not None and pol_heavy.kind in ("clone", "hedged"), (
        pol_heavy
    )
    assert pol_exp.kind != pol_heavy.kind, (pol_exp, pol_heavy)
    dt = time.perf_counter() - t0
    rows.append((
        "serving_policy_online_switch", dt * 1e6,
        f"exp={pol_exp.kind};heavy={pol_heavy.kind};B={tuner.plan.n_batches}",
    ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
