"""Length-prefixed message protocol of the multi-process cluster runtime.

One frame = a 4-byte big-endian payload length followed by a UTF-8 JSON
object with a ``"type"`` key.  JSON keeps every message inspectable with
``tcpdump``/``strace`` and the framing trivial to reimplement (the point of
a coordinator/worker split is that the two sides share nothing but this
file); 4-byte frames cap a message at 4 GiB, far above anything the control
plane sends (payload *specs* travel, payload *data* never does).

Message types (see docs/architecture.md for the full field table):

=============  ==================  ==========================================
type           direction           meaning
=============  ==================  ==========================================
REGISTER       worker -> coord     join the fleet (carries pid)
WELCOME        coord  -> worker    assigned worker_id + heartbeat interval
HEARTBEAT      worker -> coord     liveness beacon (+ currently-busy job)
DISPATCH       coord  -> worker    run one batch payload (job, attempt,
                                   payload spec, absolute deadline)
RESULT         worker -> coord     batch finished / cancel acknowledged
CANCEL         coord  -> worker    stop one (job, attempt) if still running
RECONFIGURE    coord  -> worker    new generation adopted (drain-then-swap)
CHAOS          coord  -> worker    chaos harness: multiplicative slowdown
SHUTDOWN       coord  -> worker    exit cleanly
=============  ==================  ==========================================

All senders use :func:`send_message`; receivers feed raw bytes into a
:class:`FrameDecoder` (incremental — TCP fragments frames arbitrarily).
"""

from __future__ import annotations

import collections
import json
import socket
import struct
from typing import Iterator, Optional

__all__ = [
    "REGISTER",
    "WELCOME",
    "HEARTBEAT",
    "DISPATCH",
    "RESULT",
    "CANCEL",
    "RECONFIGURE",
    "CHAOS",
    "SHUTDOWN",
    "MESSAGE_TYPES",
    "encode_message",
    "send_message",
    "FrameDecoder",
    "recv_message",
]

REGISTER = "REGISTER"
WELCOME = "WELCOME"
HEARTBEAT = "HEARTBEAT"
DISPATCH = "DISPATCH"
RESULT = "RESULT"
CANCEL = "CANCEL"
RECONFIGURE = "RECONFIGURE"
CHAOS = "CHAOS"
SHUTDOWN = "SHUTDOWN"

MESSAGE_TYPES = frozenset(
    {
        REGISTER,
        WELCOME,
        HEARTBEAT,
        DISPATCH,
        RESULT,
        CANCEL,
        RECONFIGURE,
        CHAOS,
        SHUTDOWN,
    }
)

_HEADER = struct.Struct("!I")  # 4-byte big-endian payload length
MAX_FRAME = 1 << 24  # 16 MiB: far above any control message; catches garbage


def encode_message(msg: dict) -> bytes:
    """One wire frame for ``msg`` (must carry a known ``"type"``)."""
    mtype = msg.get("type")
    if mtype not in MESSAGE_TYPES:
        raise ValueError(f"unknown message type {mtype!r}")
    payload = json.dumps(msg, separators=(",", ":")).encode("utf-8")
    if len(payload) > MAX_FRAME:
        raise ValueError(f"message of {len(payload)} bytes exceeds MAX_FRAME")
    return _HEADER.pack(len(payload)) + payload


def send_message(sock: socket.socket, msg: dict) -> None:
    """Frame and send one message (callers serialize concurrent senders)."""
    sock.sendall(encode_message(msg))


class FrameDecoder:
    """Incremental frame decoder: feed raw socket bytes, iterate messages.

    TCP is a byte stream — one ``recv`` may hold half a frame or five; the
    decoder buffers across :meth:`feed` calls and yields each complete
    message exactly once.  Frames are decoded EAGERLY into a pending queue,
    so a caller that abandons the iterator early (e.g. a take-one
    ``recv_message``) loses nothing: the leftover messages are yielded by
    the next :meth:`feed` call, even one fed no new bytes.

    >>> dec = FrameDecoder()
    >>> data = encode_message({"type": HEARTBEAT, "worker_id": 3})
    >>> [m["worker_id"] for m in dec.feed(data[:5])]
    []
    >>> [m["worker_id"] for m in dec.feed(data[5:])]
    [3]
    """

    def __init__(self) -> None:
        self._buf = bytearray()
        self._pending: collections.deque = collections.deque()

    @property
    def pending(self) -> int:
        """Decoded-but-unconsumed messages (abandoned-iterator leftovers)."""
        return len(self._pending)

    def feed(self, data: bytes) -> Iterator[dict]:
        self._buf.extend(data)
        while True:
            if len(self._buf) < _HEADER.size:
                break
            (length,) = _HEADER.unpack_from(self._buf)
            if length > MAX_FRAME:
                raise ValueError(
                    f"frame of {length} bytes exceeds MAX_FRAME — "
                    "corrupt stream or a non-protocol peer"
                )
            end = _HEADER.size + length
            if len(self._buf) < end:
                break
            payload = bytes(self._buf[_HEADER.size : end])
            del self._buf[:end]
            msg = json.loads(payload.decode("utf-8"))
            if not isinstance(msg, dict) or msg.get("type") not in MESSAGE_TYPES:
                raise ValueError(f"malformed message: {payload[:128]!r}")
            self._pending.append(msg)
        while self._pending:
            yield self._pending.popleft()


def recv_message(
    sock: socket.socket, decoder: Optional[FrameDecoder] = None
) -> Optional[dict]:
    """Blocking receive of ONE message (None on clean EOF).

    Convenience for sequential read loops and tests; the coordinator's
    selector loop feeds its per-connection decoders directly.  Extra frames
    pulled in by the same ``recv`` stay pending inside ``decoder`` — pass a
    persistent decoder (not the default throwaway) if the stream continues.
    """
    dec = decoder if decoder is not None else FrameDecoder()
    while True:
        data = sock.recv(65536)
        if not data:
            return None
        for msg in dec.feed(data):
            return msg
