"""Deterministic synthetic data pipeline implementing the paper's Fig. 1
*batching unit* and *batch assignment unit*.

The stream is a pure function of (seed, step, batch_id) — any worker can
regenerate any batch at any time, which is exactly what makes replicated
assignment and elastic re-batching cheap: re-planning B never moves data,
it only changes WHICH batch ids a data-axis coordinate pulls.

* ``global_batch(step)``            — the paper's dataset-for-this-job
* ``batch_for(step, batch_id, B)``  — the batching unit: B disjoint shards
* ``shard_for_coord(step, coord, plan)`` — the assignment unit: replica
  group members (same ``coord % B``) receive IDENTICAL data (Thm 1 balanced
  non-overlapping placement).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeCell
from repro.core.replication import ReplicationPlan, batch_index_for_data_coord

__all__ = ["TokenPipeline", "make_batch_shapes"]


def make_batch_shapes(cfg: ArchConfig, cell: ShapeCell) -> dict[str, tuple]:
    """Shapes of one GLOBAL batch for (arch, cell) — mirrors launch.input_specs."""
    b, s = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill"):
        if cfg.family == "audio":
            sd = max(s // 8, 8)
            return {
                "frames": (b, s, cfg.frontend_dim),
                "tokens": (b, sd),
                "labels": (b, sd),
            }
        if cfg.family == "vlm":
            st = s - cfg.n_patches
            return {
                "tokens": (b, st),
                "labels": (b, st),
                "patch_embeds": (b, cfg.n_patches, cfg.frontend_dim),
            }
        return {"tokens": (b, s), "labels": (b, s)}
    # decode: one new token per sequence
    return {"token": (b, 1)}


@dataclasses.dataclass
class TokenPipeline:
    cfg: ArchConfig
    cell: ShapeCell
    seed: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )

    def _materialize(self, rng, shapes: dict[str, tuple]) -> dict[str, np.ndarray]:
        out = {}
        v = self.cfg.vocab_size
        for name, shape in shapes.items():
            if name in ("tokens", "token"):
                # markovian-ish synthetic stream: correlated tokens so the
                # model has something learnable (tests train-loss decrease)
                base = rng.integers(0, v, size=shape[:1] + (1,) * (len(shape) - 1))
                noise = rng.integers(0, 17, size=shape)
                out[name] = ((base + np.cumsum(noise, axis=-1)) % v).astype(np.int32)
            elif name == "labels":
                pass  # filled from tokens below
            else:  # float embeddings (frames / patch_embeds)
                out[name] = rng.standard_normal(shape).astype(np.float32)
        if "labels" in shapes:
            toks = out["tokens"]
            lab = np.roll(toks, -1, axis=-1)
            lab[..., -1] = 0
            out["labels"] = lab.astype(np.int32)
        return out

    # -- batching unit -----------------------------------------------------
    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        return self._materialize(
            self._rng(step), make_batch_shapes(self.cfg, self.cell)
        )

    def batch_for(self, step: int, batch_id: int, n_batches: int):
        """The paper's batch i of B: rows [i*gb/B, (i+1)*gb/B) of the global
        batch, regenerated locally (deterministic pure function of step)
        rather than shipped.  Because batches are literal SLICES of the same
        global batch, the RDP gradient mean over B batches equals the plain
        DP gradient over the global batch — replication changes placement,
        never semantics."""
        shapes = make_batch_shapes(self.cfg, self.cell)
        gb = next(iter(shapes.values()))[0]
        if gb % n_batches:
            raise ValueError(f"global batch {gb} not divisible by B={n_batches}")
        rows = gb // n_batches
        full = self.global_batch(step)
        return {
            k: v[batch_id * rows : (batch_id + 1) * rows] for k, v in full.items()
        }

    # -- assignment unit ---------------------------------------------------
    def shard_for_coord(
        self, step: int, data_coord: int, plan: ReplicationPlan
    ) -> dict[str, np.ndarray]:
        """What data-axis coordinate ``data_coord`` consumes this step: the
        batch of its replica group (identical across the group — Thm 1)."""
        bid = batch_index_for_data_coord(plan, data_coord)
        return self.batch_for(step, bid, plan.n_batches)
