"""Elastic rescaling: apply a RescalePlan (tuner), a recovery Plan (fault
manager), or a shrink event to produce the next runtime configuration.

The state that survives a rescale is exactly (params, opt_state, data step)
— all placement-agnostic — so the executor's job is bookkeeping: pick the
new (N', B'), validate divisibility, and describe the new mesh factoring.
All B decisions are delegated to the unified
:class:`~repro.core.planner.Planner` control plane; in particular
:meth:`RescaleExecutor.shrink` on a skewed fleet drops the n_lost SLOWEST
workers (via ``ClusterSpec.drop_slowest``) and re-plans from the surviving
rates — not arbitrary ids.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.order_stats import ServiceDistribution
from repro.core.planner import (
    AnalyticPlanner,
    ClusterSpec,
    HeterogeneousPlanner,
    Objective,
    Plan,
    Planner,
)
from repro.core.policies import Assignment, divisors
from repro.core.replication import ReplicationPlan
from repro.core.spectrum import Metric

__all__ = ["RescaleExecutor", "RuntimeTopology"]


@dataclasses.dataclass(frozen=True)
class RuntimeTopology:
    plan: ReplicationPlan
    generation: int  # bumped on every rescale (invalidates compiled steps)
    assignment: Optional[Assignment] = None  # planner placement, if any
    dropped_workers: tuple[int, ...] = ()  # ids shed by the last shrink

    @property
    def n_workers(self) -> int:
        return self.plan.n_data


@dataclasses.dataclass
class RescaleExecutor:
    topology: RuntimeTopology
    planner: Optional[Planner] = None  # default: analytic / rate-aware

    def _planner_for(self, spec: ClusterSpec) -> Planner:
        if self.planner is not None:
            return self.planner
        return HeterogeneousPlanner() if spec.rates is not None else AnalyticPlanner()

    def apply_replan(self, new_batches: int) -> RuntimeTopology:
        plan = ReplicationPlan(
            n_data=self.topology.plan.n_data, n_batches=new_batches
        )
        self.topology = RuntimeTopology(plan, self.topology.generation + 1)
        return self.topology

    def apply_plan(self, plan: Plan) -> RuntimeTopology:
        """Adopt a full planner decision (factoring + placement)."""
        self.topology = RuntimeTopology(
            plan.replication,
            self.topology.generation + 1,
            assignment=plan.assignment,
        )
        return self.topology

    def shrink(
        self,
        n_lost: int,
        dist: Optional[ServiceDistribution] = None,
        rates: Optional[Sequence[float]] = None,
        metric: Metric = "mean",
        batch_divisor: Optional[int] = None,
    ) -> RuntimeTopology:
        """Lose ``n_lost`` workers and re-plan B for the survivors.

        * ``dist`` + ``rates``: the n_lost SLOWEST workers are shed and the
          planner re-plans from the surviving rates (rate-aware placement);
          the dropped ids are recorded on the topology.
        * ``dist`` only: homogeneous re-plan through the planner.
        * neither: no service model available — keep the largest feasible
          B <= the old B (pure bookkeeping fallback).

        ``batch_divisor`` carries the caller's data-sharding constraint
        (e.g. the global batch size) into the survivors' ClusterSpec.
        """
        old = self.topology.plan
        n_new = old.n_data - n_lost
        if n_new < 1:
            raise RuntimeError("no workers left")
        if dist is None:
            if rates is not None:
                raise ValueError("rates require a service distribution (dist)")
            b_new = max(
                b for b in divisors(n_new)
                if b <= old.n_batches
                and (batch_divisor is None or batch_divisor % b == 0)
            )
            self.topology = RuntimeTopology(
                ReplicationPlan(n_data=n_new, n_batches=b_new),
                self.topology.generation + 1,
            )
            return self.topology
        spec = ClusterSpec(
            n_workers=old.n_data,
            dist=dist,
            rates=tuple(float(r) for r in rates) if rates is not None else None,
            batch_divisor=batch_divisor,
            # shrinking never increases parallelism past the operator's
            # pre-shrink choice (same policy as FaultManager.plan_recovery
            # and the no-model fallback above)
            max_batches=old.n_batches,
        )
        spec, dropped = spec.drop_slowest(n_lost)
        plan = self._planner_for(spec).plan(spec, Objective(metric=metric))
        self.topology = RuntimeTopology(
            plan.replication,
            self.topology.generation + 1,
            assignment=plan.assignment,
            dropped_workers=dropped,
        )
        return self.topology
