"""Serving twin of Thm 4: replicated request dispatch cuts tail latency.

A fleet of N server groups serves B request batches (replication r = N/B);
batch latency = min over replicas, request completion = max over batches.
p99 shrinks monotonically with diversity (B -> 1) while mean has an interior
optimum — the same trade-off as training."""

import time

from repro.core import ShiftedExponential, divisors, simulate_maxmin


def run(n=16, trials=30_000):
    dist = ShiftedExponential(delta=0.05, mu=20.0)  # ~50ms floor service
    t0 = time.perf_counter()
    stats = {}
    for b in divisors(n):
        sim = simulate_maxmin(dist, n, b, n_trials=trials, seed=b)
        stats[b] = (sim.mean, sim.var, sim.quantile(0.99))
    dt = (time.perf_counter() - t0) / len(stats)
    variances = {b: v[1] for b, v in stats.items()}
    # Thm 4 is about VARIANCE (jitter): minimized at full diversity.  The
    # p99 itself includes the deterministic NΔ/B shift, so its optimum can
    # sit elsewhere — exactly the paper's mean/variance trade-off.
    assert variances[1] == min(variances.values())
    best_mean = min(stats, key=lambda b: stats[b][0])
    best_p99 = min(stats, key=lambda b: stats[b][2])
    return [
        (
            "serving_tail_latency",
            dt * 1e6,
            f"var_B*=1;mean_B*={best_mean};p99_B*={best_p99};"
            + ";".join(
                f"B{b}:mean={m*1e3:.1f}ms,sd={v**0.5*1e3:.1f}ms,p99={p*1e3:.1f}ms"
                for b, (m, v, p) in stats.items()
            ),
        )
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
