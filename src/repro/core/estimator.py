"""MLE fitting of the service-time distribution from runtime telemetry.

The tuner observes per-worker step times.  Two complications vs textbook MLE:

* **Right censoring** — when the runtime cancels stragglers (or a step
  finishes because every batch has a fast replica), slow workers' times are
  only known to exceed the step's cutoff.  We support censored samples.
* **Model selection** — Exp vs SExp: we fit both and pick by (censored)
  log-likelihood with a small penalty for the extra parameter (AIC).

Shifted-exponential MLE (uncensored): Delta_hat = X_(1) (sample min),
mu_hat = 1 / (mean(X) - X_(1)).  We apply the standard small-sample
bias correction Delta_hat -= (mean - min)/(n-1) when requested.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .order_stats import Exponential, ServiceDistribution, ShiftedExponential

__all__ = ["FitResult", "fit_exponential", "fit_shifted_exponential", "fit_best"]


@dataclasses.dataclass(frozen=True)
class FitResult:
    dist: ServiceDistribution
    log_likelihood: float
    n_samples: int
    n_censored: int

    @property
    def aic(self) -> float:
        k = 2 if isinstance(self.dist, ShiftedExponential) else 1
        return 2 * k - 2 * self.log_likelihood


def _validate(samples, censored):
    x = np.asarray(samples, dtype=float)
    if x.ndim != 1 or x.size == 0:
        raise ValueError("samples must be a non-empty 1-D array")
    if np.any(~np.isfinite(x)) or np.any(x < 0):
        raise ValueError("samples must be finite and non-negative")
    if censored is None:
        c = np.zeros(x.shape, dtype=bool)
    else:
        c = np.asarray(censored, dtype=bool)
        if c.shape != x.shape:
            raise ValueError("censored mask must match samples shape")
    if c.all():
        raise ValueError("at least one uncensored observation required")
    return x, c


def fit_exponential(samples, censored=None) -> FitResult:
    """Censored MLE for Exp(mu): mu_hat = n_uncensored / sum(all times)."""
    x, c = _validate(samples, censored)
    n_unc = int((~c).sum())
    total = float(x.sum())
    if total <= 0:
        raise ValueError("sum of observation times must be positive")
    mu = n_unc / total
    # log L = n_unc * log(mu) - mu * sum(x)   (censored terms contribute -mu*c_i)
    ll = n_unc * math.log(mu) - mu * total
    return FitResult(Exponential(mu=mu), ll, int(x.size), int(c.sum()))


def fit_shifted_exponential(
    samples, censored=None, bias_correct: bool = True
) -> FitResult:
    """Censored MLE for SExp(Delta, mu).

    Delta_hat = min over UNCENSORED observations (a censored time > Delta
    carries no extra information about the shift as long as it exceeds the
    min).  Given Delta, the exponential part uses the censored-Exp MLE on
    (x - Delta) clipped at 0 for censored entries that are below Delta
    (cannot happen for valid data, guarded anyway).
    """
    x, c = _validate(samples, censored)
    unc = x[~c]
    delta = float(unc.min())
    n_unc = int(unc.size)
    if bias_correct and n_unc > 1:
        excess_mean = float(unc.mean() - delta)
        delta = max(0.0, delta - excess_mean / (n_unc - 1))
    shifted = np.clip(x - delta, 0.0, None)
    total = float(shifted.sum())
    if total <= 0:
        # degenerate: all mass at the shift; fall back to a very fast rate
        mu = 1e12
    else:
        mu = n_unc / total
    ll = n_unc * math.log(mu) - mu * total
    return FitResult(
        ShiftedExponential(delta=delta, mu=mu), ll, int(x.size), int(c.sum())
    )


def fit_best(samples, censored=None) -> FitResult:
    """Fit both families, return the lower-AIC one.

    A fitted SExp with Delta ~ 0 collapses to Exp; the AIC penalty breaks the
    tie toward the 1-parameter family.
    """
    fe = fit_exponential(samples, censored)
    try:
        fs = fit_shifted_exponential(samples, censored)
    except ValueError:
        return fe
    return fs if fs.aic < fe.aic else fe
