"""Assignment policies + System1 simulator (Thm 1 numerics)."""

import numpy as np
import pytest

from repro.core import (
    Exponential,
    FaultEvent,
    ShiftedExponential,
    StepTimeSimulator,
    balanced_nonoverlapping,
    completion_from_step_times,
    divisors,
    overlapping_cyclic,
    random_assignment,
    simulate_coverage,
    simulate_maxmin,
    unbalanced_nonoverlapping,
)


def test_divisors():
    assert divisors(16) == [1, 2, 4, 8, 16]
    assert divisors(1) == [1]
    assert divisors(12) == [1, 2, 3, 4, 6, 12]


def test_balanced_assignment_structure():
    a = balanced_nonoverlapping(12, 4)
    assert a.n_batches == 4
    assert a.batch_sizes == (3, 3, 3, 3)
    assert a.replication == (3, 3, 3, 3)
    assert not a.is_overlapping
    assert a.coverage_matrix().sum() == 12 * 3


def test_coverage_equals_maxmin_for_balanced():
    d = Exponential(mu=1.0)
    a = balanced_nonoverlapping(8, 4)
    s1 = simulate_coverage(d, a, n_trials=4000, seed=5)
    s2 = simulate_maxmin(d, 8, 4, n_trials=4000, seed=5)
    np.testing.assert_allclose(s1.samples, s2.samples)


def test_overlapping_is_worse_thm1():
    d = Exponential(mu=1.0)
    bal = simulate_coverage(d, balanced_nonoverlapping(16, 4), 8000, seed=1)
    ovl = simulate_coverage(d, overlapping_cyclic(16, 4), 8000, seed=1)
    assert bal.mean < ovl.mean


def test_unbalanced_is_worse_thm1():
    d = ShiftedExponential(delta=0.2, mu=1.0)
    bal = simulate_coverage(
        d, balanced_nonoverlapping(8, 4), 20000, seed=2
    )
    unb = simulate_coverage(
        d, unbalanced_nonoverlapping(8, [1, 1, 3, 3]), 20000, seed=2
    )
    assert bal.mean < unb.mean


def test_random_assignment_no_better_than_balanced():
    d = Exponential(mu=2.0)
    bal = simulate_coverage(d, balanced_nonoverlapping(8, 4), 10000, seed=3)
    rnd = simulate_coverage(d, random_assignment(8, 4, seed=9), 10000, seed=3)
    assert bal.mean <= rnd.mean + 3 * (bal.stderr + rnd.stderr)


def test_completion_from_step_times_uses_fastest_replica():
    a = balanced_nonoverlapping(4, 2)  # workers 0,1 -> batch 0; 2,3 -> batch 1
    times = np.array([3.0, 1.0, 9.0, 2.0])
    t, used = completion_from_step_times(times, a)
    assert t == 2.0  # max(min(3,1), min(9,2))
    assert used.tolist() == [False, True, False, True]


def test_completion_with_dead_batch_is_inf():
    a = balanced_nonoverlapping(4, 2)
    times = np.array([np.inf, np.inf, 1.0, 2.0])
    t, used = completion_from_step_times(times, a)
    assert np.isinf(t)
    assert used.tolist() == [False, False, True, False]


def test_step_time_simulator_faults_and_slowdowns():
    sim = StepTimeSimulator(
        Exponential(mu=5.0),
        4,
        seed=0,
        slow_workers={1: 100.0},
        faults=[FaultEvent(worker=2, start_step=1, end_step=3)],
    )
    t0 = sim.next_step()
    assert np.isfinite(t0).all()
    t1 = sim.next_step()
    assert np.isinf(t1[2])
    # persistent slow worker dominates the fleet median over many steps
    slows = [sim.next_step() for _ in range(50)]
    med = np.median([s[1] for s in slows])
    rest = np.median([s[0] for s in slows])
    assert med > 10 * rest
