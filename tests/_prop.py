"""Property-testing shim: real hypothesis when installed, fallback otherwise.

The tier-1 suite must collect and pass in environments without
``hypothesis`` (the container image does not ship it).  When hypothesis IS
available we re-export it untouched — full shrinking, fuzzing, the works.
When it is not, ``@given`` degrades to a deterministic fixed-seed example
sweep: each strategy draws ``max_examples`` values (boundary values first,
then seeded-random), and the test body runs once per example.  That keeps
the property tests meaningful (they still sweep the domain) without the
dependency.

Usage in test modules::

    from _prop import given, settings, st

(the tests/ directory is on sys.path under pytest's default import mode).
"""

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import zlib

    import numpy as _np

    class _Strategy:
        """A draw rule: example(rng, i) -> value.  i==0/1 hit the domain
        boundaries so every sweep covers the edges; larger i are random."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng, i):
            return self._draw(rng, i)

    class _StrategiesShim:
        @staticmethod
        def floats(min_value, max_value, **_kw):
            lo, hi = float(min_value), float(max_value)

            def draw(rng, i):
                if i == 0:
                    return lo
                if i == 1:
                    return hi
                return float(rng.uniform(lo, hi))

            return _Strategy(draw)

        @staticmethod
        def integers(min_value, max_value):
            lo, hi = int(min_value), int(max_value)

            def draw(rng, i):
                if i == 0:
                    return lo
                if i == 1:
                    return hi
                return int(rng.integers(lo, hi + 1))

            return _Strategy(draw)

        @staticmethod
        def sampled_from(options):
            opts = list(options)

            def draw(rng, i):
                if i < len(opts):
                    return opts[i]
                return opts[int(rng.integers(len(opts)))]

            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng, i):
                size = min_size + i % (max_size - min_size + 1)
                return [
                    elements.example(rng, 2 + int(rng.integers(1 << 20)))
                    for _ in range(size)
                ]

            return _Strategy(draw)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng, i: bool(i % 2) if i < 2 else bool(rng.integers(2)))

    st = _StrategiesShim()

    def given(*args, **strategies):
        if args:
            raise NotImplementedError(
                "fallback @given supports keyword strategies only"
            )

        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                cfg = getattr(wrapper, "_prop_settings", {})
                n = int(cfg.get("max_examples", 20))
                seed = zlib.crc32(fn.__name__.encode())
                rng = _np.random.default_rng(seed)
                for i in range(n):
                    drawn = {
                        name: s.example(rng, i) for name, s in strategies.items()
                    }
                    fn(**drawn)

            # pytest introspects the signature through __wrapped__ and would
            # demand fixtures for the strategy parameters; hide the original.
            del wrapper.__wrapped__
            return wrapper

        return deco

    def settings(**kwargs):
        """Record max_examples etc.; works above or below @given because
        functools.wraps copies the attribute onto the sweep wrapper."""

        def deco(fn):
            fn._prop_settings = dict(kwargs)
            return fn

        return deco
