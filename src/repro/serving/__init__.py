"""Discrete-event replicated serving: arrivals -> queueing master -> engine."""

from repro.serving.arrivals import (
    ArrivalProcess,
    DeterministicArrivals,
    MMPPArrivals,
    PoissonArrivals,
    TraceArrivals,
    make_arrivals,
)
from repro.serving.engine import (
    ReplicatedServingEngine,
    RequestStats,
    ServeEngineConfig,
)
from repro.serving.queueing import (
    BatchJob,
    ClonePolicy,
    EventDrivenMaster,
    HedgedDispatchPolicy,
    NoOpPolicy,
    QueuePolicy,
    RelaunchPolicy,
    Request,
    SpeculationPolicy,
    StragglerPolicy,
    partition_requests,
)

__all__ = [
    "ArrivalProcess",
    "BatchJob",
    "ClonePolicy",
    "DeterministicArrivals",
    "EventDrivenMaster",
    "HedgedDispatchPolicy",
    "MMPPArrivals",
    "NoOpPolicy",
    "PoissonArrivals",
    "QueuePolicy",
    "RelaunchPolicy",
    "ReplicatedServingEngine",
    "Request",
    "RequestStats",
    "ServeEngineConfig",
    "SpeculationPolicy",
    "StragglerPolicy",
    "TraceArrivals",
    "make_arrivals",
    "partition_requests",
]
