"""Qwen2-0.5B: GQA kv=2 with QKV bias, tied embeddings.

[arXiv:2407.10671; hf:Qwen/Qwen2-0.5B] 24L d_model=896 14H (GQA kv=2)
d_ff=4864 vocab=151936.

14 heads do not divide the 16-way model axis — attention projections use
HEAD-DIM sharding (head_dim=64 splits 16-way); FFN/vocab shard normally.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    subquadratic=False,
)
