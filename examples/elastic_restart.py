"""Fault-tolerance walkthrough: kill BOTH replicas of a batch mid-training,
watch the runtime detect the lost replica group, restore from checkpoint,
shrink the fleet, re-plan B, and keep training.

Recovery routes through the unified planner: the trainer's FaultManager
builds a survivors-only ClusterSpec and calls Planner.plan — the same entry
point the online tuner and the serving engine use.  The second act shows the
elastic layer's skew-aware shrink directly: with per-worker rates known, the
executor sheds the SLOWEST workers, not arbitrary ids.

Run: PYTHONPATH=src python examples/elastic_restart.py
"""

import numpy as np

from repro.core import FaultEvent, ReplicationPlan, ShiftedExponential
from repro.distributed import RescaleExecutor, RuntimeTopology
from repro.launch.train import Trainer, TrainerConfig


def main():
    faults = (
        # batch 1's replicas on an 8-worker B=4 plan are coords 1 and 5
        FaultEvent(worker=1, start_step=20, end_step=10**9),
        FaultEvent(worker=5, start_step=20, end_step=10**9),
    )
    tc = TrainerConfig(
        arch="qwen2-0.5b",
        steps=60,
        seq_len=64,
        global_batch=16,
        n_workers=8,
        n_batches=4,
        faults=faults,
        checkpoint_dir="/tmp/repro_elastic",
        checkpoint_every=10,
        seed=0,
    )
    res = Trainer(tc).run()
    print(f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")
    print(f"plan history (step, B): {res.plan_history}")
    print("events:")
    for e in res.events:
        print("  ", e)
    assert any("replan" in e for e in res.events), "expected an elastic replan"
    assert res.final_plan.n_data < 8
    assert np.isfinite(res.losses).all()
    print(f"\nOK: survived a whole-replica-group loss; now on "
          f"N={res.final_plan.n_data}, B={res.final_plan.n_batches}")

    print("\n=== Skew-aware shrink (planner-driven) ===")
    # a 16-worker fleet with two crippled hosts; preemption takes 2 workers
    rates = list(np.linspace(1.2, 0.8, 16))
    rates[4], rates[9] = 0.05, 0.08
    ex = RescaleExecutor(RuntimeTopology(ReplicationPlan(16, 8), generation=0))
    topo = ex.shrink(2, dist=ShiftedExponential(delta=0.5, mu=2.0), rates=rates)
    print(f"dropped workers {topo.dropped_workers} (the crippled hosts), "
          f"re-planned to N={topo.plan.n_data}, B={topo.plan.n_batches}")
    assert topo.dropped_workers == (4, 9)


if __name__ == "__main__":
    main()
