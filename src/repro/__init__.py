"""repro: straggler-resilient distributed training/serving framework in JAX.

Reproduction of Behrouzi-Far & Soljanin, 'Data Replication for Reducing
Computing Time in Distributed Systems with Stragglers' (2019), extended into
a production-grade multi-pod framework.  See DESIGN.md.
"""

__version__ = "0.1.0"
