"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps under the replication runtime, with checkpointing, the
diversity-parallelism tuner, and a straggler injection at step 150.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params; a few minutes on CPU.  --small for a 2-minute variant.)
"""

import argparse
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.launch.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    tc = TrainerConfig(
        arch="qwen2-0.5b",
        reduced=args.small,
        steps=args.steps,
        seq_len=128 if args.small else 256,
        global_batch=16,
        n_workers=8,
        n_batches=4,
        lr=1e-3,
        warmup=30,
        service="sexp",
        delta=0.5,
        mu=2.0,
        slow_workers={5: 25.0},
        tuner=True,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=100,
        seed=0,
    )
    if not args.small:
        # ~100M-param variant of the qwen2 family (same code path)
        base = get_config("qwen2-0.5b")
        cfg = dataclasses.replace(
            base, name="qwen2-100m", n_layers=12, d_model=512, n_heads=8,
            n_kv_heads=2, d_ff=2048, vocab_size=32000,
        )
        trainer = Trainer(tc)
        # swap in the 100M config before params are used
        from repro.models import init_params
        import jax

        trainer.cfg = cfg
        trainer.params = init_params(jax.random.PRNGKey(0), cfg)
        from repro.optim import init as opt_init

        trainer.opt_state = opt_init(trainer.params, trainer.adamw)
        from repro.data import TokenPipeline
        from repro.configs.base import ShapeCell

        trainer.pipeline = TokenPipeline(
            cfg, ShapeCell("driver", tc.seq_len, tc.global_batch, "train"),
            seed=tc.seed,
        )
        from repro.models import count_params

        print(f"model: {cfg.name} ({count_params(cfg)/1e6:.0f}M params)")
    else:
        trainer = Trainer(tc)

    res = trainer.run()
    print(f"\nloss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"over {len(res.losses)} steps")
    print(f"simulated wall-clock: {res.total_sim_time:.0f}s "
          f"(host wall: {res.wall_time:.0f}s)")
    print(f"plan history (step, B): {res.plan_history}")
    for e in res.events[:10]:
        print("  ", e)
    assert np.mean(res.losses[-20:]) < np.mean(res.losses[:20])
    print("OK: loss decreased under stragglers + replication runtime")


if __name__ == "__main__":
    main()
