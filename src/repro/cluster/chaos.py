"""Chaos harness: scripted faults against a running LocalCluster.

A :class:`ChaosSchedule` is a list of :class:`ChaosEvent` entries, each an
``(at, kind, worker, arg)`` tuple on the coordinator clock.  The harness
injects them while the coordinator's event loop runs, using the real OS
mechanisms a production straggler/failure would arrive through:

==========  ===============================================================
kind        mechanism
==========  ===============================================================
``kill``    SIGKILL the worker process — socket EOFs, coordinator must
            re-dispatch its in-flight batch and re-plan for the survivors
``pause``   SIGSTOP — heartbeats stop mid-batch; past ``heartbeat_timeout``
            the coordinator declares death.  ``arg`` seconds later the
            harness SIGCONTs and the worker rejoins (flap path: its stale
            RESULT must be ignored)
``slow``    CHAOS protocol message — worker multiplies payload durations by
            ``arg`` (an invisible straggler; only telemetry can see it)
``spawn``   launch one extra worker process (elastic growth / late join)
==========  ===============================================================

Injection is driven by :meth:`ChaosInjector.tick` from the same loop that
drives the coordinator (``drive()``), so event times are deterministic
relative to the run.
"""

from __future__ import annotations

import dataclasses
import os
import signal
from typing import Optional

from repro.cluster import protocol
from repro.cluster.harness import LocalCluster

__all__ = ["ChaosEvent", "ChaosInjector", "drive"]

_KINDS = ("kill", "pause", "slow", "spawn")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scripted fault.

    ``at``     — coordinator-clock seconds.
    ``kind``   — 'kill' | 'pause' | 'slow' | 'spawn'.
    ``worker`` — target worker_id ('spawn' ignores it).
    ``arg``    — pause: resume after this many seconds; slow: the factor;
                 spawn: register_delay.
    """

    at: float
    kind: str
    worker: int = 0
    arg: float = 0.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r} (use {_KINDS})")
        if self.at < 0:
            raise ValueError(f"event time must be >= 0, got {self.at}")
        if self.kind == "pause" and self.arg < 0:
            raise ValueError("pause resume delay must be >= 0")
        if self.kind == "slow" and self.arg <= 0:
            raise ValueError(f"slowdown factor must be > 0, got {self.arg}")


class ChaosInjector:
    """Fires a schedule of ChaosEvents against a cluster as time passes."""

    def __init__(self, cluster: LocalCluster, events: list[ChaosEvent]):
        self.cluster = cluster
        self._events = sorted(events, key=lambda e: e.at)
        self._resumes: list[tuple[float, int]] = []  # (at, pid) SIGCONTs
        self.fired: list[ChaosEvent] = []

    def _signal(self, worker_id: int, sig: int) -> Optional[int]:
        coord = self.cluster.coordinator
        handle = coord.workers.get(worker_id)
        if handle is None or handle.pid <= 0:
            return None
        try:
            os.kill(handle.pid, sig)
        except ProcessLookupError:
            return None
        return handle.pid

    def tick(self) -> None:
        """Fire every event whose time has come (call from the drive loop)."""
        coord = self.cluster.coordinator
        now = coord.now()
        while self._events and self._events[0].at <= now:
            ev = self._events.pop(0)
            if ev.kind == "kill":
                self._signal(ev.worker, signal.SIGKILL)
            elif ev.kind == "pause":
                pid = self._signal(ev.worker, signal.SIGSTOP)
                if pid is not None and ev.arg > 0:
                    self._resumes.append((now + ev.arg, pid))
            elif ev.kind == "slow":
                coord._send(
                    ev.worker,
                    {"type": protocol.CHAOS, "slowdown": float(ev.arg)},
                )
            elif ev.kind == "spawn":
                self.cluster.spawn_worker(register_delay=ev.arg)
            self.fired.append(ev)
        still = []
        for at, pid in self._resumes:
            if at <= now:
                try:
                    os.kill(pid, signal.SIGCONT)
                except ProcessLookupError:
                    pass
            else:
                still.append((at, pid))
        self._resumes = still

    @property
    def pending(self) -> int:
        return len(self._events) + len(self._resumes)


def drive(
    cluster: LocalCluster,
    injector: Optional[ChaosInjector] = None,
    *,
    timeout: float = 60.0,
) -> list:
    """Run the coordinator to completion, ticking the injector each lap.

    The injector piggybacks on the coordinator's poll cadence, so a fault
    scheduled at t=0.5 fires within one poll interval of 0.5s on the
    coordinator clock.  Returns the completed requests.
    """
    coord = cluster.coordinator
    deadline = coord.now() + timeout
    while coord._resolved < len(coord._submitted) or (
        injector is not None and injector.pending
    ):
        if coord.now() > deadline:
            raise TimeoutError(
                f"chaos run incomplete after {timeout}s "
                f"({coord._resolved}/{len(coord._submitted)} resolved, "
                f"{injector.pending if injector else 0} chaos events pending)"
            )
        if injector is not None:
            injector.tick()
        if (
            not any(t[2] in ("arrival", "form") for t in coord._timers)
            and len(coord._admission)
        ):
            while len(coord._admission):
                coord._form(
                    min(len(coord._admission), coord.config.batch_size)
                )
        coord._poll(0.02)
    return list(coord._submitted)
