from repro.optim.adamw import AdamWConfig, global_norm, init, state_specs, update
from repro.optim.schedules import constant, warmup_cosine

__all__ = [
    "AdamWConfig",
    "global_norm",
    "init",
    "state_specs",
    "update",
    "constant",
    "warmup_cosine",
]
