"""Fault tolerance + straggler detection (control plane).

On a real pod the signals are host heartbeats and per-step barrier timings;
here the same logic runs against :class:`repro.core.simulator.StepTimeSimulator`
so every policy is CPU-testable.

* :class:`StragglerDetector` — one-step-delayed control (DESIGN.md §2):
  flags workers whose recent service times are k-sigma/medians above the
  fleet, emits the ``alive`` mask consumed by the weighted psum.
* :class:`FaultManager` — tracks hard failures (missed heartbeats), decides
  between *mask* (batch still covered by surviving replicas) and *elastic
  restart* (a whole replica group lost).  Recovery B is NOT chosen here:
  :meth:`FaultManager.plan_recovery` builds a survivors-only
  :class:`~repro.core.planner.ClusterSpec` and delegates to the unified
  :class:`~repro.core.planner.Planner` control plane.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

import numpy as np

from repro.core.order_stats import ServiceDistribution
from repro.core.planner import (
    AnalyticPlanner,
    ClusterSpec,
    HeterogeneousPlanner,
    Objective,
    Plan,
    Planner,
)
from repro.core.policies import Assignment
from repro.core.replication import ReplicationPlan, batch_index_for_data_coord
from repro.core.spectrum import Metric

__all__ = ["StragglerDetector", "FaultManager", "FaultDecision"]


@dataclasses.dataclass
class StragglerDetector:
    n_workers: int
    window: int = 20
    threshold: float = 3.0  # flag if time > threshold * fleet median
    min_history: int = 5

    def __post_init__(self):
        self._hist: deque[np.ndarray] = deque(maxlen=self.window)

    def observe(self, step_times: np.ndarray) -> None:
        t = np.asarray(step_times, dtype=float)
        if t.shape != (self.n_workers,):
            raise ValueError(f"expected ({self.n_workers},), got {t.shape}")
        self._hist.append(t)

    def drop_mask(self) -> np.ndarray:
        """True = keep.  Workers persistently slower than threshold x median
        get dropped from the NEXT step's aggregation (their replica group
        still covers the batch)."""
        if len(self._hist) < self.min_history:
            return np.ones(self.n_workers, dtype=bool)
        h = np.stack(self._hist)  # (w, n)
        finite = np.where(np.isfinite(h), h, np.nan)
        per_worker = np.nanmedian(finite, axis=0)
        fleet = np.nanmedian(per_worker)
        mask = per_worker <= self.threshold * fleet
        dead = np.isnan(per_worker)
        return mask & ~dead


@dataclasses.dataclass(frozen=True)
class FaultDecision:
    kind: str  # 'ok' | 'mask' | 'replan'
    alive: np.ndarray  # per-worker keep mask
    lost_batches: tuple[int, ...] = ()

    @property
    def needs_restart(self) -> bool:
        return self.kind == "replan"


@dataclasses.dataclass
class FaultManager:
    plan: ReplicationPlan
    heartbeat_misses_fatal: int = 3
    planner: Optional[Planner] = None  # recovery solver (default: analytic)

    def __post_init__(self):
        self._missed = np.zeros(self.plan.n_data, dtype=int)

    def heartbeat(self, responded: np.ndarray) -> None:
        responded = np.asarray(responded, dtype=bool)
        self._missed = np.where(responded, 0, self._missed + 1)

    def mark_dead(self, worker: int) -> None:
        """Declare one worker dead IMMEDIATELY (no miss-count grace).

        The wall-clock cluster runtime has death signals stronger than a
        missed poll — a socket EOF when a worker process is SIGKILLed, or a
        heartbeat gap past the hard timeout — and routes them here so
        :meth:`decide` / :meth:`plan_recovery` see the loss on the next
        step without waiting ``heartbeat_misses_fatal`` polls.
        """
        if not 0 <= worker < self.plan.n_data:
            raise ValueError(
                f"worker {worker} out of range [0, {self.plan.n_data})"
            )
        self._missed[worker] = self.heartbeat_misses_fatal

    def dead_mask(self) -> np.ndarray:
        """True = dead."""
        return self._missed >= self.heartbeat_misses_fatal

    def decide(
        self,
        straggler_keep: Optional[np.ndarray] = None,
        assignment: Optional[Assignment] = None,
    ) -> FaultDecision:
        """Combine hard faults + straggler drops into the step decision.

        ``assignment`` supplies the active worker->batch map (rate-aware
        placements differ from the canonical replica-major layout); without
        it the plan's replica-major coordinate map is used.
        """
        alive = ~self.dead_mask()
        if straggler_keep is not None:
            alive = alive & np.asarray(straggler_keep, dtype=bool)
        if assignment is not None:
            if assignment.n_workers != self.plan.n_data:
                raise ValueError(
                    f"assignment covers {assignment.n_workers} workers but "
                    f"plan has {self.plan.n_data} — stale placement?"
                )
            n_batches = assignment.n_batches
            batch_of = assignment.worker_batch
        else:
            n_batches = self.plan.n_batches
            batch_of = [
                batch_index_for_data_coord(self.plan, w)
                for w in range(self.plan.n_data)
            ]
        # which batches still have at least one live replica?
        covered = np.zeros(n_batches, dtype=bool)
        for w in range(self.plan.n_data):
            if alive[w]:
                covered[batch_of[w]] = True
        lost = tuple(int(b) for b in np.nonzero(~covered)[0])
        if lost:
            return FaultDecision("replan", alive, lost)
        if not alive.all():
            return FaultDecision("mask", alive)
        return FaultDecision("ok", alive)

    def plan_recovery(
        self,
        dist: ServiceDistribution,
        rates: Optional[Sequence[float]] = None,
        batch_divisor: Optional[int] = None,
        metric: Metric = "mean",
    ) -> Plan:
        """Re-plan for the surviving fleet through the unified planner.

        Builds a ClusterSpec of the heartbeat-alive workers (keeping their
        per-worker ``rates`` if known), constrains B to at most the
        pre-fault value (recovery never increases parallelism past what the
        operator chose) and to divide ``batch_divisor`` when given (e.g. the
        global batch size), then delegates to the Planner.
        """
        alive = ~self.dead_mask()
        n_alive = int(alive.sum())
        if n_alive < 1:
            raise RuntimeError("no workers left")
        surviving_rates = None
        if rates is not None:
            r = np.asarray(rates, dtype=float)
            if r.shape != (self.plan.n_data,):
                raise ValueError(
                    f"rates shape {r.shape} != ({self.plan.n_data},)"
                )
            surviving_rates = tuple(float(x) for x in r[alive])
        spec = ClusterSpec(
            n_workers=n_alive,
            dist=dist,
            rates=surviving_rates,
            batch_divisor=batch_divisor,
            max_batches=self.plan.n_batches,
        )
        planner = self.planner
        if planner is None:
            planner = (
                HeterogeneousPlanner()
                if surviving_rates is not None
                else AnalyticPlanner()
            )
        return planner.plan(spec, Objective(metric=metric))
