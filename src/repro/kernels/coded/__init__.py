"""Encode/decode kernels for coded computation (PR 9).

Coding is only a win if its overhead is MEASURED, not assumed free: a
coded plan pays an encode (coefficient-combine of the data blocks before
dispatch) and a decode (weight-combine of the first k responses) that
replication never pays.  This package supplies that combine as one kernel
body on the repo's three backend lanes — numpy reference, jit JAX, Pallas
(CPU ``interpret=True``) — plus :func:`~.ops.measure_coding_overhead`,
the wall-clock probe the planner uses to resolve
``CodingCandidate(encode_overhead=None)`` before scoring the candidate.
"""

from .ops import (
    BACKENDS,
    coded_combine,
    decode_combine,
    encode_matrix,
    measure_coding_overhead,
)

__all__ = [
    "BACKENDS",
    "coded_combine",
    "decode_combine",
    "encode_matrix",
    "measure_coding_overhead",
]
