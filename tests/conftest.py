import os
import sys

# smoke tests / benches see ONE device; the dry-run (and only it) forces 512
# in its own process.  Keep compilation deterministic & quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make the _prop shim importable regardless of pytest import mode / cwd
sys.path.insert(0, os.path.dirname(__file__))

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session", autouse=True)
def _reap_cluster_workers():
    """Kill any worker subprocess a crashed/failed cluster test leaves
    behind, so one bad test can't strand orphan processes that hold the
    session (or the CI runner) hostage."""
    yield
    from repro.cluster.harness import reap_orphans

    reaped = reap_orphans()
    if reaped:
        print(f"\n[conftest] reaped {reaped} orphaned cluster worker(s)")
