"""Cyclic gradient coding (the cited alternative scheme) — decode
correctness + order-statistic closed forms + the comparison result."""

import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import Exponential, ShiftedExponential
from repro.core.gradient_coding import (
    CyclicGradientCode,
    compare_schemes,
    expected_coding_time,
    simulate_gradient_coding,
)


def test_assignment_structure():
    code = CyclicGradientCode(n_workers=6, s=2)
    a = code.assignment()
    assert a.sum(axis=1).tolist() == [3] * 6  # each worker: s+1 batches
    assert a.sum(axis=0).tolist() == [3] * 6  # each batch: s+1 replicas
    assert code.overhead == 3


@settings(deadline=None, max_examples=20)
@given(
    n=st.sampled_from([4, 6, 8]),
    s=st.integers(0, 3),
    seed=st.integers(0, 100),
)
def test_decode_any_n_minus_s_workers(n, s, seed):
    """Tandon Thm 1: ANY N-s workers suffice to decode the batch sum."""
    if s >= n:
        return
    code = CyclicGradientCode(n_workers=n, s=s)
    rng = np.random.default_rng(seed)
    alive = np.zeros(n, dtype=bool)
    alive[rng.choice(n, size=n - s, replace=False)] = True
    w = code.decode_weights(alive)
    assert w is not None
    b = code.coefficients()[alive]
    np.testing.assert_allclose(b.T @ w, 1.0, atol=1e-6)
    # decoding a synthetic gradient: sum of batch gradients recovered
    g_batches = rng.standard_normal((n, 5))
    worker_msgs = b @ g_batches  # each worker sends its coded sum
    recovered = w @ worker_msgs
    np.testing.assert_allclose(recovered, g_batches.sum(0), atol=1e-4)


def test_decode_fails_below_threshold():
    code = CyclicGradientCode(n_workers=6, s=2)
    alive = np.array([True, True, True, False, False, False])
    assert alive.sum() < 6 - 2 + 1  # only 3 < 4 alive
    assert code.decode_weights(alive) is None


@pytest.mark.parametrize("s", [0, 1, 3])
def test_closed_form_matches_mc(s):
    dist = ShiftedExponential(delta=0.3, mu=2.0)
    mc = simulate_gradient_coding(dist, 8, s, n_trials=100_000, seed=s)
    cf = expected_coding_time(dist, 8, s)
    assert abs(mc.mean - cf) < 5 * mc.stderr + 1e-3


def test_replication_beats_coding_iid():
    """The ablation headline: at equal storage overhead under i.i.d.
    stragglers, the paper's replication wins every interior point."""
    cmp = compare_schemes(
        ShiftedExponential(delta=0.3, mu=2.0), 16, n_trials=20_000
    )
    for oh, v in cmp["common"].items():
        if 1 < oh < 16:
            assert v["replication"] < v["coding"], (oh, v)


def test_s0_equals_full_parallelism():
    """s=0 coding == B=N replication (both wait for everyone)."""
    from repro.core import simulate_maxmin

    dist = Exponential(mu=1.0)
    cod = simulate_gradient_coding(dist, 8, 0, n_trials=50_000, seed=3)
    rep = simulate_maxmin(dist, 8, 8, n_trials=50_000, seed=4)
    assert abs(cod.mean - rep.mean) < 4 * (cod.stderr + rep.stderr)
