"""Numpy reference for the scan-formulated sojourn/policy cell recursion.

The event-driven recursions in ``repro.core.simulator`` (heap of
arrive/depart/trigger events) are re-expressed here as a **job-ordered
scan**, which is what the jnp/Pallas kernels implement.  The two are
exactly equivalent under the simulator's FIFO master:

* jobs dispatch in arrival order, so the scan axis is the job index;
* between two dispatches the replica-set ``free`` times are piecewise
  constant except at trigger firings, so "some set is idle at t" is just
  ``min(free) <= t`` — no event queue is needed to answer it;
* a clone trigger armed at ``trig`` with re-arm period ``threshold``
  fires at the first re-arm instant with an idle set and disarms if the
  primary departs first, so its effective fire time is found by stepping
  ``t += threshold`` while ``t < done`` and ``t < min(free)`` — the same
  float additions the event loop performs, which is what makes the f64
  outputs *bit-identical* to the event-driven recursions (pinned in
  ``tests/test_sojourn_kernel.py``);
* armed triggers across sets are resolved chronologically (ties broken
  by job id, matching the event heap's push-order sequence numbers)
  before each dispatch, and drained after the last one.

Policy kinds are encoded as integers shared with the jnp kernels:
``0=none, 1=clone, 2=relaunch, 3=hedged``.  Hedge decisions are supplied
as a precomputed per-job boolean mask (the deterministic-stride rule
``floor((n+1)f) > floor(nf)`` evaluated in f64 on the host) so the f32
device path cannot diverge from the f64 reference on the stride
arithmetic.

All arithmetic stays in the dtype of the inputs (numpy scalar ops do not
upcast), so the same code doubles as the f32 oracle for the jnp backends.
"""

from __future__ import annotations

import numpy as np

KIND_NONE = 0
KIND_CLONE = 1
KIND_RELAUNCH = 2
KIND_HEDGED = 3


def _effective_fire_times(free, doneg, trig, kind, threshold, inf):
    """Effective next-event time of each armed trigger under current state.

    For relaunch the trigger fires unconditionally at ``trig``; for clone
    at the first re-arm instant with an idle set (``min(free)`` at or
    below it).  If the primary departs first the group's next event is the
    *depart* at ``doneg`` (which finalizes the job and disarms), so the
    effective time is capped at ``doneg`` — the event heap processes the
    depart before any later re-arm check.
    """
    m = np.min(free)
    eff = trig.copy()
    if kind == KIND_CLONE:
        for g in range(len(trig)):
            t = trig[g]
            while t < doneg[g] and t < m:
                t = t + threshold
            eff[g] = t
    return np.minimum(eff, doneg), m


def _resolve_events(free, doneg, trig, jobid, out, arrivals, alt, kind,
                    threshold, limit_arrival, inf, extra):
    """Fire/disarm armed triggers chronologically up to the next dispatch.

    ``limit_arrival`` is the pending job's arrival time (``inf`` to drain
    after the last dispatch); the next dispatch happens at
    ``max(limit_arrival, min(free))``, re-evaluated after every firing
    because clones raise ``min(free)`` and relaunches can lower it.
    """
    while True:
        armed = np.isfinite(trig)
        if not armed.any():
            return extra
        eff, m = _effective_fire_times(free, doneg, trig, kind, threshold, inf)
        eff = np.where(armed, eff, inf)
        start = max(limit_arrival, m)
        t_min = eff.min()
        # Earliest event; ties broken by job id (event-heap push order).
        cand = np.flatnonzero(eff == t_min)
        g = cand[np.argmin(jobid[cand])]
        t = eff[g]
        jid = int(jobid[g])
        # Fires happen strictly before the next dispatch; departs (disarm +
        # finalize) also at the dispatch instant itself — the heap orders a
        # depart ahead of the dispatch it enables.
        disarm = t >= doneg[g]
        if not (t_min < start or (t_min <= start and disarm)):
            return extra
        if disarm:
            done_new = doneg[g]           # primary departed first: disarm
        elif kind == KIND_CLONE:
            idle = np.flatnonzero(free <= t)
            h = idle[np.argmin(free[idle])]
            done_new = min(doneg[g], t + alt[jid, h])
            free[h] = done_new
            extra += 1
        else:                             # KIND_RELAUNCH: cancel + fresh draw
            done_new = t + alt[jid, g]
            extra += 1
        free[g] = done_new
        doneg[g] = done_new
        trig[g] = inf
        out[jid] = done_new - arrivals[jid]


def sojourn_cell_reference(arrivals, svc, alt, kind, threshold, hedge_mask,
                           n_groups):
    """Scan-formulated sojourn recursion for one (dist, B, policy) cell.

    Parameters
    ----------
    arrivals : (J,) float array of absolute arrival times (non-decreasing).
    svc, alt : (J, G) float arrays of primary / redundant service draws per
        replica set; only the first ``n_groups`` columns are read.
    kind : int policy code (``KIND_*``).
    threshold : float trigger delay for clone/relaunch (``inf`` disables).
    hedge_mask : (J,) bool array — job i dispatches a hedge iff set (only
        read for ``KIND_HEDGED``).
    n_groups : int number of replica sets ``B``.

    Returns
    -------
    (out, extra) : (J,) float sojourn times and the int count of extra
        (clone / relaunch / hedge) dispatches.
    """
    arrivals = np.asarray(arrivals)
    svc = np.asarray(svc)
    alt = np.asarray(alt)
    dtype = svc.dtype
    n_jobs = arrivals.shape[0]
    inf = dtype.type(np.inf)
    threshold = dtype.type(threshold)

    free = np.zeros(n_groups, dtype=dtype)
    doneg = np.zeros(n_groups, dtype=dtype)
    trig = np.full(n_groups, inf, dtype=dtype)
    jobid = np.full(n_groups, -1, dtype=np.int64)
    out = np.zeros(n_jobs, dtype=dtype)
    extra = 0
    armed_policy = kind in (KIND_CLONE, KIND_RELAUNCH) and np.isfinite(threshold)

    for i in range(n_jobs):
        a = arrivals[i]
        if armed_policy:
            extra = _resolve_events(free, doneg, trig, jobid, out, arrivals,
                                    alt, kind, threshold, a, inf, extra)
        start = max(a, free.min())
        g = int(np.argmin(free))          # lowest index among ties
        done = start + svc[i, g]
        if armed_policy:
            free[g] = done
            doneg[g] = done
            trig[g] = start + threshold
            jobid[g] = i
            continue
        if kind == KIND_HEDGED and hedge_mask[i]:
            idle = np.flatnonzero(free <= start)
            idle = idle[idle != g]
            if idle.size:
                h = idle[np.argmin(free[idle])]
                done = min(done, start + alt[i, h])
                free[h] = done
                extra += 1
        free[g] = done
        out[i] = done - a

    if armed_policy:
        extra = _resolve_events(free, doneg, trig, jobid, out, arrivals, alt,
                                kind, threshold, inf, inf, extra)
    return out, extra


def coded_completion_reference(times, ks):
    """k-th-order-statistic completion of coded cells (numpy oracle).

    ``times`` is (C, T, N): per-cell worker service times, already
    load-scaled on the shared CRN draws; ``ks[c]`` is the completion
    quorum (the job finishes once any k workers respond).  Returns the
    (C, T) completion times.  Selection is value-exact — the output IS
    one of the input floats — so at equal dtype the jnp backends are
    bit-identical, the same layered contract as the sojourn cells.
    """
    times = np.asarray(times)
    ks = np.asarray(ks, dtype=np.int64)
    n_cells, _, n_workers = times.shape
    if ks.shape != (n_cells,):
        raise ValueError(f"ks shape {ks.shape} != ({n_cells},)")
    if np.any(ks < 1) or np.any(ks > n_workers):
        raise ValueError(f"ks must be in [1, N={n_workers}], got {ks}")
    out = np.empty(times.shape[:2], dtype=times.dtype)
    for c in range(n_cells):
        out[c] = np.sort(times[c], axis=1)[:, ks[c] - 1]
    return out


def sojourn_cells_reference(arrivals, svc, alt, kinds, thresholds,
                            hedge_masks, n_groups):
    """Batched reference: all (cell, policy) pairs via the scalar kernel.

    Shapes mirror :func:`repro.kernels.sojourn_sweep.ops.sojourn_policy_cells`:
    ``svc``/``alt`` are (C, J, G), ``thresholds`` is (C, P), ``kinds`` and
    ``hedge_masks`` are per-policy ((P,) and (P, J)), ``n_groups`` is (C,).
    Returns ``(out (C, P, J), extra (C, P))``.
    """
    svc = np.asarray(svc)
    n_cells, n_jobs, _ = svc.shape
    n_pol = len(kinds)
    out = np.zeros((n_cells, n_pol, n_jobs), dtype=svc.dtype)
    extra = np.zeros((n_cells, n_pol), dtype=np.int64)
    for c in range(n_cells):
        for p in range(n_pol):
            out[c, p], extra[c, p] = sojourn_cell_reference(
                arrivals, svc[c], alt[c], int(kinds[p]),
                thresholds[c][p], hedge_masks[p], int(n_groups[c]))
    return out, extra
