"""Accelerator-resident planning sweep vs the legacy numpy path (PR 8).

The tuner's quality-of-decision cost is re-plan latency: the full
load-aware sweep scores every (bootstrap resample, B, policy) cell, and
before PR 8 each cell was one Python heap simulation
(``_sojourn_recursion*``).  This bench pins the headline numbers on the
fleet-scale configuration — N=10k workers, an Empirical service pool of
10k atoms, the full (B, policy) grid over B ∈ {50, 100, 200} × 4 policy
kinds, J=300 jobs per cell:

* ``sweep_numpy_k4`` — the legacy numpy ``sweep_sojourn_policies`` path
  on a K=4 resample subset.  The numpy path is one independent Python
  simulation per cell, so its cost is linear in K; the speedup rows
  scale this measurement to their K (documented in ``derived`` as
  ``numpy_scaled_s``).
* ``sweep_accel_fleet_k256`` — the jax backend (jit+vmap scan kernel,
  grouped per-split/per-policy dispatch) on the full K=256 bootstrap
  grid: 3072 cells, one sweep call.  Asserts the >=20x acceptance bar
  over the K-scaled numpy path.
* ``replan_accel_k20`` — the same fleet and grid at the tuner's DEFAULT
  bootstrap budget (``TunerConfig.bootstrap_resamples = 20``), i.e. the
  re-plan the production tuner issues per observation window.  Asserts
  the <1s acceptance bar (warm caches — the steady state of the tuner
  loop) and the >=20x ratio at that K.  This is the number that makes
  ``TunerConfig.replan_time_budget=1.0`` waive cooldown pacing.

Timings here are wall-clock on whatever host runs the bench; the
committed baseline was produced on a single-core CPU runner, where the
"accelerator" backend is XLA:CPU — on a real accelerator the gap widens
(the numpy path cannot use the device at all).
"""

import time

import numpy as np

from repro.core.order_stats import Empirical
from repro.core.policies import PolicyCandidate
from repro.core.simulator import sweep_sojourn_policies

N_WORKERS = 10_000
SPLITS = (50, 100, 200)
N_JOBS = 300
ARRIVAL_RATE = 40.0
N_ATOMS = 10_000
K_FLEET = 256
K_REPLAN = 20  # TunerConfig.bootstrap_resamples default
K_NUMPY = 4  # numpy subset actually timed (cost is linear in K)
POLICIES = (
    PolicyCandidate("none"),
    PolicyCandidate("clone", quantile=0.85),
    PolicyCandidate("relaunch", quantile=0.9),
    PolicyCandidate("hedged", hedge_fraction=0.3),
)


def _resamples(k: int) -> list[Empirical]:
    rng = np.random.default_rng(0)
    pool = rng.gamma(2.0, 0.5, N_ATOMS)
    return [Empirical(rng.choice(pool, pool.size)) for _ in range(k)]


def _sweep(dists, backend):
    return sweep_sojourn_policies(
        dists,
        n_workers=N_WORKERS,
        arrival_rate=ARRIVAL_RATE,
        policies=POLICIES,
        n_jobs=N_JOBS,
        seed=3,
        feasible_b=list(SPLITS),
        backend=backend,
    )


def _warm_best(dists, backend, n=3):
    _sweep(dists, backend)  # compile + populate the shared-CRN caches
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        res = _sweep(dists, backend)
        best = min(best, time.perf_counter() - t0)
    return best, res


def run():
    rows = []
    pool = _resamples(K_FLEET)
    grid = f"N={N_WORKERS};B={list(SPLITS)};policies={len(POLICIES)};" \
           f"jobs={N_JOBS}"

    # min of 3 (the load-spike-resistant timing statistic, and the
    # CONSERVATIVE side for the speedup asserts below — a single sample
    # is noisy enough on a shared host to flip them by several x)
    samples = []
    for _ in range(3):
        t0 = time.perf_counter()
        _sweep(pool[:K_NUMPY], "numpy")
        samples.append(time.perf_counter() - t0)
    t_numpy = min(samples)
    rows.append((
        "sweep_numpy_k4",
        t_numpy * 1e6,
        f"{grid};K={K_NUMPY};cost linear in K (independent Python sim "
        f"per cell)",
    ))

    t_fleet, res = _warm_best(pool, "jax")
    numpy_fleet = t_numpy * K_FLEET / K_NUMPY
    speedup = numpy_fleet / t_fleet
    assert res.backend == "jax", res.backend
    assert speedup >= 20.0, (
        f"fleet-scale sweep speedup {speedup:.1f}x below the 20x "
        f"acceptance bar (accel {t_fleet:.2f}s vs numpy-scaled "
        f"{numpy_fleet:.1f}s)"
    )
    rows.append((
        "sweep_accel_fleet_k256",
        t_fleet * 1e6,
        f"{grid};K={K_FLEET};numpy_scaled_s={numpy_fleet:.1f};"
        f"speedup={speedup:.1f}x",
    ))

    t_replan, res = _warm_best(pool[:K_REPLAN], "jax")
    numpy_replan = t_numpy * K_REPLAN / K_NUMPY
    speedup = numpy_replan / t_replan
    assert t_replan < 1.0, (
        f"warm re-plan took {t_replan:.2f}s, above the 1s acceptance bar"
    )
    assert speedup >= 20.0, (
        f"re-plan speedup {speedup:.1f}x below the 20x acceptance bar"
    )
    rows.append((
        "replan_accel_k20",
        t_replan * 1e6,
        f"{grid};K={K_REPLAN} (tuner default bootstrap budget);"
        f"numpy_scaled_s={numpy_replan:.1f};speedup={speedup:.1f}x;"
        f"sub_second={t_replan < 1.0}",
    ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
