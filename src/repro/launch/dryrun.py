import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  512 host devices stand in for 2 pods x 256 chips.

Per cell this script:
  1. builds the production mesh (16x16 or 2x16x16) and the auto policy,
  2. builds ShapeDtypeStruct inputs (zero allocation),
  3. jit(step).lower(...).compile()  with explicit in/out shardings,
  4. prints memory_analysis() and cost_analysis(),
  5. parses the optimized HLO for collective bytes,
  6. writes reports/dryrun/<arch>__<shape>__<mesh>.json for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all            # every runnable cell
  python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import pathlib
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: pathlib.Path,
             rdp_batches: int | None = None) -> dict:
    import jax
    from repro.configs import SHAPE_CELLS, cell_supported, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.policies import auto_policy
    from repro.launch.specs import input_specs
    from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
    from repro.roofline.analysis import analyze_compiled

    import dataclasses

    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    ok, reason = cell_supported(cfg, cell)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if rdp_batches:
        mesh_name = f"rdp{rdp_batches}x{mesh_name}"
    tag = f"{arch}__{shape}__{mesh_name}"
    if not ok:
        return {"cell": tag, "status": "skipped", "reason": reason}

    t0 = time.time()
    if rdp_batches:
        # the paper's technique on the mesh: data extent factored into
        # (replica, batch); replica strides across pods -> gradient traffic
        # never crosses the pod boundary (DESIGN.md §2.4)
        from repro.launch.mesh import make_rdp_production_mesh

        mesh, plan = make_rdp_production_mesh(
            rdp_batches, multi_pod=multi_pod
        )
        policy = auto_policy(cfg, cell, mesh)
        policy = dataclasses.replace(policy, dp_axes=("batch",))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        policy = auto_policy(cfg, cell, mesh)
    args, specs = input_specs(cfg, cell, policy, mesh)

    if cell.kind == "train":
        step = make_train_step(cfg, policy, mesh)
    elif cell.kind == "prefill":
        step = make_prefill_step(cfg, policy, mesh, max_len=cell.seq_len)
    else:
        step = make_decode_step(cfg, policy, mesh)

    from jax.sharding import NamedSharding

    in_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax wraps the dict in a list
        cost = cost[0] if cost else None
    print(f"[{tag}] memory_analysis: {mem}")
    flops = cost.get("flops", 0.0) if cost else 0.0
    print(f"[{tag}] cost_analysis: flops={flops:.3e} "
          f"bytes={cost.get('bytes accessed', 0.0):.3e}" if cost else "n/a")

    report = analyze_compiled(
        compiled, cfg, cell, mesh, policy,
        lower_s=t_lower, compile_s=t_compile,
    )
    report["cell"] = tag
    report["status"] = "ok"
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{tag}.json").write_text(json.dumps(report, indent=2))
    print(f"[{tag}] lower {t_lower:.1f}s compile {t_compile:.1f}s -> ok")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rdp-batches", type=int, default=None,
                    help="factor the data extent into (replica, B) per the paper")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, SHAPE_CELLS

    out_dir = pathlib.Path(args.out)
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPE_CELLS:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp, out_dir,
                         rdp_batches=args.rdp_batches)
            except Exception as e:  # noqa: BLE001 — report and continue
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
